"""HLO-text analysis: collective wire bytes + roofline terms.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but NOT collective
traffic; we parse the (SPMD-partitioned, per-device) HLO text and apply
ring-algorithm wire formulas per op (documented in EXPERIMENTS.md):

  all-gather          out_bytes * (n-1)/n        (out = gathered, local)
  all-reduce          2 * out_bytes * (n-1)/n
  reduce-scatter      out_bytes * (n-1)           (out = scattered shard)
  all-to-all          out_bytes * (n-1)/n
  collective-permute  out_bytes
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

import numpy as np

from repro.core import precision_table

# Canonical table lives in core/precision_table.py.
_DTYPE_BYTES = precision_table.DTYPE_BYTES

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_PARAM_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\[\],\{\}]+))\s+parameter\(")


def parameter_bytes(hlo_text: str, dtypes=None) -> int:
    """Total bytes of the ENTRY computation's parameters.

    ``dtypes`` optionally restricts to a set of HLO dtype names (e.g.
    ``{"u16", "u32"}`` isolates the packed GSE matrix segments from the
    float vector/table operands).  Used by ``perf.ledger`` to cross-check
    the modeled matrix-stream bytes against what a compiled kernel
    actually takes as inputs.
    """
    total = 0
    in_entry = False
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            in_entry = line.lstrip().startswith("ENTRY")
            continue
        if not in_entry:
            continue
        m = _PARAM_RE.search(line)
        if not m:
            continue
        for sm in _SHAPE_RE.finditer(m.group(1)):
            dt = sm.group(1)
            if dt not in _DTYPE_BYTES or (dtypes is not None
                                          and dt not in dtypes):
                continue
            n = 1
            for d in sm.group(2).split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
    return total


# Computation headers sit at column 0 ("%name (args) -> type {" / "ENTRY ..");
# instruction lines are indented.
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)"
)
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _wire_bytes(line: str) -> Tuple[str, float]:
    m = _COLL_RE.search(line)
    if not m:
        return "", 0.0
    tuple_types, single_type, kind = m.group(1), m.group(2), m.group(3)
    out_bytes = _shape_bytes(tuple_types if tuple_types else single_type)
    gm = _GROUPS_RE.search(line)
    if gm:
        n = len([x for x in gm.group(1).split(",") if x.strip() != ""])
    else:
        gm2 = _GROUPS_V2_RE.search(line)
        n = int(gm2.group(2)) if gm2 else 2
    n = max(n, 2)
    if kind == "all-gather":
        wire = out_bytes * (n - 1) / n
    elif kind == "all-reduce":
        wire = 2 * out_bytes * (n - 1) / n
    elif kind == "reduce-scatter":
        wire = out_bytes * (n - 1)
    elif kind == "all-to-all":
        wire = out_bytes * (n - 1) / n
    else:  # collective-permute
        wire = out_bytes
    return kind, wire


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float],
                                             Dict[str, int]]:
    """Per-device wire bytes by collective kind (ring formulas above).

    Computation-aware: collectives inside a ``while`` body (layer scans)
    are multiplied by the loop trip count, recovered from the integer
    bound in the loop condition computation (max s32 constant -- exact for
    XLA's canonical scan lowering, documented heuristic otherwise).
    """
    comp_text = segment_computations(hlo_text)
    multiplier = while_multipliers(comp_text)

    by_kind: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for cname, lines in comp_text.items():
        mult = multiplier.get(cname, 1.0)
        for line in lines:
            kind, wire = _wire_bytes(line)
            if kind:
                by_kind[kind] = by_kind.get(kind, 0.0) + wire * mult
                counts[kind] = counts.get(kind, 0) + int(mult)
    return sum(by_kind.values()), by_kind, counts


def segment_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Split HLO text by computation (headers sit at column 0)."""
    comp_text: Dict[str, List[str]] = {}
    cur = "__top__"
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            hdr = _COMP_HDR_RE.match(line)
            if hdr:
                cur = hdr.group(1)
    # second pass with state (avoid walrus confusion)
        comp_text.setdefault(cur, []).append(line)
    return comp_text


def while_multipliers(comp_text: Dict[str, List[str]]) -> Dict[str, float]:
    """body/cond computation -> product of enclosing while trip counts.

    Trip counts come from XLA's ``known_trip_count`` backend config on the
    while op (exact for scan lowerings); fallback: max s32 constant in the
    loop condition.
    """
    whiles = []  # (parent, cond, body, trips)
    for cname, lines in comp_text.items():
        for line in lines:
            if " while(" not in line:
                continue
            m = _WHILE_RE.search(line)
            if not m:
                continue
            cond, body = m.group(1), m.group(2)
            tm = _TRIP_RE.search(line)
            if tm:
                trips = int(tm.group(1))
            else:
                consts = []
                for cl in comp_text.get(cond, []):
                    consts += [int(c) for c in _CONST_RE.findall(cl)]
                trips = max(consts) if consts else 1
            whiles.append((cname, cond, body, max(trips, 1)))

    mult = {name: 1.0 for name in comp_text}
    for _ in range(4):  # nested whiles fixpoint
        for parent, cond, body, trips in whiles:
            mult[body] = mult.get(parent, 1.0) * trips
            mult[cond] = mult[body]
    return mult


def while_trip_counts(hlo_text: str) -> List[int]:
    """Best-effort scan trip counts (collectives inside while bodies execute
    trip_count times; the parser multiplies them in)."""
    return [int(m.group(1)) for m in
            re.finditer(r"trip_count=(\d+)", hlo_text)]


# ---------------------------------------------------------------------------
# While-aware FLOPs / bytes analysis (XLA's HloCostAnalysis counts while
# bodies ONCE -- wrong by num_layers for scanned stacks; we re-derive).
# ---------------------------------------------------------------------------

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[\w\[\],\{\}]+))\s+"
    r"([\w\-]+)\(([^)]*(?:\([^)]*\)[^)]*)*)\)"
)
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "rsqrt", "sqrt", "negate", "abs", "log",
    "logistic", "select", "compare", "and", "or", "xor", "convert",
    "floor", "cosine", "sine", "clamp",
}


def _dims(shape_str: str) -> List[List[int]]:
    return [
        [int(d) for d in m.group(2).split(",") if d]
        for m in _SHAPE_RE.finditer(shape_str)
        if m.group(1) in _DTYPE_BYTES
    ]


def analyze(hlo_text: str) -> Dict:
    """While-aware per-device FLOPs, HBM-ish bytes, collective wire bytes.

    FLOPs: exact for dot ops (2 * prod(out_dims) * K), 1 FLOP/elem for
    elementwise arithmetic.  Bytes: operands + outputs per instruction
    (fusion nodes count their boundary, internals excluded) -- the same
    accounting HloCostAnalysis uses, but multiplied through while loops.
    Returns dict(flops, bytes, coll_bytes, coll_by_kind, coll_counts,
    top_dots).
    """
    # --- segment computations; build symbol table name -> bytes/shape ---
    comp_lines = segment_computations(hlo_text)

    sym_bytes: Dict[str, int] = {}
    sym_shape: Dict[str, str] = {}
    instrs: Dict[str, List[Tuple[str, str, str, str]]] = {}
    fusion_bodies = set()
    for cname, lines in comp_lines.items():
        for line in lines:
            for m in _CALLS_RE.finditer(line):
                if "calls=" in m.group(0) or "to_apply=" in m.group(0):
                    fusion_bodies.add(m.group(1))
            im = _INSTR_RE.match(line)
            if not im:
                continue
            name, typ, op, operands = im.groups()
            sym_bytes[name] = _shape_bytes(typ)
            sym_shape[name] = typ
            instrs.setdefault(cname, []).append((name, typ, op, line))

    mult = while_multipliers(comp_lines)

    # Consumer map: expansion fusions (convert / GSE-SEM decode) whose
    # every consumer is a dot never hit HBM on TPU -- the Pallas
    # gse_matmul kernel decodes segments in VMEM and feeds the MXU
    # directly (kernels/gse_matmul.py, interpret-validated).  Skip their
    # output-write accounting.
    consumers: Dict[str, set] = {}
    for cname, items in instrs.items():
        for name, typ, op, line in items:
            for on in re.findall(r"%([\w\.\-]+)",
                                 line.split("(", 1)[1] if "(" in line
                                 else ""):
                consumers.setdefault(on, set()).add(op)
    vmem_resident = set()
    for cname, items in instrs.items():
        for name, typ, op, line in items:
            if op != "fusion":
                continue
            ops_ = re.findall(r"%([\w\.\-]+)",
                              line.split("(", 1)[1] if "(" in line else "")
            in_b = sum(sym_bytes.get(o, 0) for o in ops_)
            out_b = sym_bytes.get(name, 0)
            cons = consumers.get(name, set())
            if 0 < in_b < out_b and cons and cons <= {"dot"}:
                vmem_resident.add(name)

    _SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "while", "after-all", "custom-call",
                   "reshape", "iota", "conditional", "call"}

    # def map: instruction name -> (op, operand names) for chain walking.
    def_map: Dict[str, Tuple[str, List[str]]] = {}
    for cname, items in instrs.items():
        for name, typ, op, line in items:
            ops_ = re.findall(r"%([\w\.\-]+)",
                              line.split("(", 1)[1] if "(" in line else "")
            def_map[name] = (op, ops_)

    def _native_bytes(opname: str) -> int:
        """Bytes of a dot operand at its NATIVE storage dtype.

        XLA:CPU legalizes bf16 math by materializing f32 copies (a
        convert/kLoop-fusion feeding the dot); XLA:TPU feeds bf16 (or the
        GSE-SEM u16 segments via the Pallas gse_matmul kernel, which
        decodes in VMEM) straight to the MXU.  Charge the cheapest
        single-hop source when the producer is a convert-like fusion whose
        inputs are smaller than its output.
        """
        b = sym_bytes.get(opname, 0)
        cur = opname
        # Walk through pass-through ops to the producing computation.
        for _ in range(6):
            d = def_map.get(cur)
            if not d:
                return b
            op, ops_ = d
            if op in ("get-tuple-element", "bitcast", "reshape", "copy",
                      "transpose") and ops_:
                cur = ops_[0]
                continue
            break
        d = def_map.get(cur)
        if not d:
            return b
        op, ops_ = d
        if op == "convert" and ops_:
            src = sym_bytes.get(ops_[0], 0)
            return min(b, src) if src else b
        if op == "fusion":
            in_b = sum(sym_bytes.get(o, 0) for o in ops_)
            if 0 < in_b < b:  # expansion fusion (convert / decode): charge in
                return in_b
        return b

    def _instr_bytes(op: str, name: str, typ: str, line: str) -> float:
        """HBM bytes for one instruction.

        Slice-family ops read/write only the slice (counting the full
        operand would overcount scanned stacked weights by num_layers).
        For everything else: output + operands, with each operand capped
        at max(4x output, 1 MiB) -- fusions that internally slice a large
        buffer would otherwise bill the whole buffer (documented
        approximation; reduction fusions undercount at most 4x).
        """
        out_b = sym_bytes.get(name, 0)
        if op in ("dynamic-slice", "slice", "gather", "transpose", "pad",
                  "reverse", "copy", "concatenate"):
            return 2.0 * out_b
        if op == "broadcast":
            return float(out_b)
        opnames = re.findall(r"%([\w\.\-]+)",
                             line.split("(", 1)[1] if "(" in line else "")
        if op == "dynamic-update-slice":
            upd = sym_bytes.get(opnames[1], out_b) if len(opnames) > 1 else out_b
            return 2.0 * upd
        if op == "fusion" and "dynamic-update-slice" in name:
            # Loop-carried in-place cache update: XLA:CPU materializes the
            # whole carried buffer per iteration, XLA:TPU aliases it.  Bill
            # TPU semantics: 2x the true update slice (the smallest operand
            # of the fused DUS).
            cands = [sym_bytes.get(o, 0) for o in opnames
                     if 0 < sym_bytes.get(o, 0) < max(out_b // 8, 1 << 30)]
            upd = min(cands) if cands else out_b
            return 2.0 * upd
        if op == "scatter":
            upd = sym_bytes.get(opnames[2], out_b) if len(opnames) > 2 else out_b
            return 2.0 * upd + out_b * 0  # read-modify-write of touched rows
        if op == "dot":
            b = float(out_b)
            for on in opnames:
                b += _native_bytes(on)
            return b
        if op in ("reduce", "sort", "convolution"):
            b = float(out_b)
            for on in opnames:
                b += sym_bytes.get(on, 0)
            return b
        cap = max(4.0 * out_b, float(1 << 20))
        b = float(out_b)
        for on in opnames:
            b += min(float(sym_bytes.get(on, 0)), cap)
        return b
    flops = 0.0
    mem_bytes = 0.0
    coll_total = 0.0
    coll_by_kind: Dict[str, float] = {}
    coll_counts: Dict[str, int] = {}
    dots: List[Tuple[float, str]] = []

    for cname, items in instrs.items():
        if cname in fusion_bodies and cname not in mult:
            continue
        m_ = mult.get(cname, 1.0)
        in_fusion_body = cname in fusion_bodies
        for name, typ, op, line in items:
            if in_fusion_body and op != "dot":
                continue  # fusion internals: only dots contribute FLOPs
            kind, wire = _wire_bytes(line)
            if kind:
                # Charge the wire at the operand's NATIVE dtype: XLA:CPU
                # legalizes bf16 by inserting f32 converts before the
                # collective; on TPU the collective moves bf16 directly.
                opn = re.findall(r"%([\w\.\-]+)",
                                 line.split("(", 1)[1] if "(" in line else "")
                if opn:
                    raw = sym_bytes.get(opn[0], 0)
                    nat = _native_bytes(opn[0])
                    if raw > 0 and 0 < nat < raw:
                        wire *= nat / raw
                coll_total += wire * m_
                coll_by_kind[kind] = coll_by_kind.get(kind, 0.0) + wire * m_
                coll_counts[kind] = coll_counts.get(kind, 0) + int(m_)
            if op == "dot":
                out_elems = 0
                for dl in _dims(typ):
                    e = 1
                    for d in dl:
                        e *= d
                    out_elems += e
                k = 1
                dm = _DOT_DIMS_RE.search(line)
                opnames = re.findall(r"%([\w\.\-]+)", line.split("(", 1)[1])
                if dm and opnames:
                    lhs_shape = sym_shape.get(opnames[0], "")
                    ldims = _dims(lhs_shape)
                    if ldims:
                        for ci in [int(c) for c in dm.group(1).split(",") if c]:
                            if ci < len(ldims[0]):
                                k *= ldims[0][ci]
                f = 2.0 * out_elems * k * m_
                flops += f
                dots.append((f, typ + " <- " + sym_shape.get(
                    opnames[0] if opnames else "", "?")))
            elif op in _ELEMWISE:
                out_elems = 0
                for dl in _dims(typ):
                    e = 1
                    for d in dl:
                        e *= d
                    out_elems += e
                flops += out_elems * m_
            if (not in_fusion_body) and op not in _SKIP_BYTES:
                if name in vmem_resident:
                    # charge only the segment reads; output stays in VMEM
                    opn = re.findall(
                        r"%([\w\.\-]+)",
                        line.split("(", 1)[1] if "(" in line else "")
                    mem_bytes += sum(sym_bytes.get(o, 0) for o in opn) * m_
                else:
                    mem_bytes += _instr_bytes(op, name, typ, line) * m_

    dots.sort(reverse=True)
    return {
        "flops": flops,
        "bytes": mem_bytes,
        "coll_bytes": coll_total,
        "coll_by_kind": coll_by_kind,
        "coll_counts": coll_counts,
        "top_dots": dots[:12],
    }


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, hw) -> Dict[str, float]:
    t_comp = flops_per_dev / hw.PEAK_FLOPS_BF16
    t_mem = bytes_per_dev / hw.HBM_BW
    t_coll = coll_bytes_per_dev / hw.ICI_BW
    terms = {
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
    }
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("t_", "").replace("_s", "")
    bound = max(t_comp, t_mem, t_coll)
    terms["roofline_fraction"] = t_comp / bound if bound > 0 else 0.0
    return terms
