"""launch subpackage."""
