"""Assigned input shapes x skip logic + ShapeDtypeStruct input specs.

Shapes (assignment):
  train_4k     seq_len=4096    global_batch=256   (train_step)
  prefill_32k  seq_len=32768   global_batch=32    (prefill forward)
  decode_32k   seq_len=32768   global_batch=128   (serve_step, 1 new token)
  long_500k    seq_len=524288  global_batch=1     (serve_step; sub-quadratic
                                                   archs only -- see skips)

Skips (DESIGN.md §6): long_500k runs only for ssm/hybrid families; the 8
pure-full-attention archs skip it.  Modality frontends are stubs --
``input_specs`` supplies precomputed frame/patch embeddings.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

SHAPES: Dict[str, Dict] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.supports_long_context():
        return False, (
            "full-attention arch: 500k dense-KV decode is quadratic-history;"
            " skipped per assignment (sub-quadratic archs only)"
        )
    return True, ""


def cells():
    """All (arch, shape) pairs incl. skip annotations."""
    from repro import configs

    out = []
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        for shape in SHAPES:
            ok, why = applicable(cfg, shape)
            out.append((arch, shape, ok, why))
    return out


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of the step fn.

    Shape budget conventions (documented in EXPERIMENTS.md §Dry-run):
      * encdec train/prefill: seq_len splits 50/50 encoder frames vs
        decoder tokens (total positions == seq_len).
      * vlm: 256 patch embeddings are part of the seq_len budget
        (text tokens = seq_len - 256).
    """
    spec = SHAPES[shape_name]
    b, s = spec["global_batch"], spec["seq_len"]
    kind = spec["kind"]
    f32 = jnp.float32
    i32 = jnp.int32

    if kind in ("train", "prefill"):
        if cfg.family == "encdec":
            s_enc, s_dec = s // 2, s // 2
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, s_dec), i32),
                "labels": jax.ShapeDtypeStruct((b, s_dec), i32),
                "loss_mask": jax.ShapeDtypeStruct((b, s_dec), f32),
                "enc_embeds": jax.ShapeDtypeStruct((b, s_enc, cfg.d_model),
                                                   f32),
            }
        elif cfg.family == "vlm":
            p = cfg.num_prefix_tokens
            st = s - p
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, st), i32),
                "labels": jax.ShapeDtypeStruct((b, st), i32),
                "loss_mask": jax.ShapeDtypeStruct((b, st), f32),
                "prefix_embeds": jax.ShapeDtypeStruct((b, p, cfg.d_model),
                                                      f32),
            }
        else:
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
                "loss_mask": jax.ShapeDtypeStruct((b, s), f32),
            }
        if kind == "prefill":
            batch.pop("labels")
            batch.pop("loss_mask")
        return batch

    # decode: one new token against a seq_len-deep cache
    out = {
        "tokens": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
    if cfg.family == "encdec":
        out["enc_out"] = jax.ShapeDtypeStruct((b, s // 2, cfg.d_model), f32)
    return out


def batch_logical_axes(batch_spec: Dict) -> Dict:
    """Logical axes for each batch input (-> in_shardings)."""
    ax = {}
    for k, v in batch_spec.items():
        if k == "pos":
            ax[k] = ()
        elif k in ("tokens", "labels", "loss_mask"):
            ax[k] = ("batch",) + (("seq",) if len(v.shape) == 2 else ())
        elif k in ("prefix_embeds", "enc_embeds", "enc_out"):
            ax[k] = ("batch", "seq", "act_embed")
        else:
            raise KeyError(k)
    return ax
