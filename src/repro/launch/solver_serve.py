"""Request-batching solve service over registered GSE-SEM operators.

The ROADMAP's serving-shaped front-end for the linear-solver path
(DESIGN.md §11): heavy traffic means MANY simultaneous solve requests
against a few shared operators.  The service packs each registered
matrix (and optional preconditioner) ONCE, buckets incoming requests by
(operator, tolerance), pads each bucket to a fixed batch-slot width, and
runs the batched stepped solver -- one streaming pass over the packed
matrix segments feeds every request in a slot, so the dominant matrix
traffic is charged once per iteration however many requests ride along
(``csr.iteration_stream_bytes(..., nrhs=...)``).

Per-request reporting: iterations, final relative residual, the
per-column tag-switch schedule, and the request's modeled byte share of
its batch (matrix bytes split evenly across the iterations' active
columns, vector bytes owned per column).  Padding columns are all-zero
right-hand sides: ``||b|| = 0`` makes them converge at iteration 0, so
they never stream vector bytes and never perturb real requests (the
batched solver's columns are independent by construction).

Usage (demo):
  PYTHONPATH=src python -m repro.launch.solver_serve --requests 6 --slots 4
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import precision as P
from repro.core.tagmap import TagMap, normalize_tags
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.robustness.guards import (
    DEFAULT_GUARDS,
    GuardParams,
    HEALTH_NONFINITE,
    HEALTH_OK,
    health_name,
)
from repro.sparse.csr import CSR, GSESellC, iteration_stream_bytes, pack_csr
from repro.solvers.batched import (
    column_tags_at,
    solve_cg_batched,
    solve_pcg_batched,
)
from repro.solvers.cg import solve_cg, solve_pcg
from repro.solvers.precond import make_jacobi, make_spai0

__all__ = ["SolveRequest", "SolveReport", "SolverService"]

_PRECOND_FACTORY = {"jacobi": make_jacobi, "spai0": make_spai0}

# Distinguishes the metric series of multiple SolverService instances in
# one process (tests build them freely); the id is a label value, so all
# instances share ONE registered family per metric name.
_SERVICE_IDS = itertools.count()


def _normalize_service_tags(tags, m: int, sharded: bool = False,
                            sell: bool = False):
    """Validate/normalize a service-level ``tags=`` precision axis.

    ``None`` -> the handle/monitor default.  An int or a uniform
    :class:`~repro.core.tagmap.TagMap` normalizes to the int tag (the
    legacy fast path); a NON-uniform map stays a map (single-device
    handles only -- the sharded decode has no per-group pack yet, same
    restriction as the solvers' ``tags=``).  ``"adaptive"`` selects the
    data-driven driver, which reads the flat ``GSECSR`` pack -- so it
    needs a single-device CSR handle.
    """
    if tags is None:
        return None
    if isinstance(tags, str):
        if tags != "adaptive":
            raise ValueError(
                f"tags= accepts an int tag, a TagMap, or 'adaptive'; "
                f"got {tags!r}")
        if sharded or sell:
            raise ValueError(
                "tags='adaptive' needs a single-device CSR handle "
                "(solve_adaptive reads the flat GSECSR pack)")
        return "adaptive"
    norm = normalize_tags(tags, m)
    if isinstance(norm, TagMap) and sharded:
        raise ValueError(
            "per-group tag maps are single-device; the sharded serve "
            "path takes int tags only")
    return norm


def _tags_token(tags):
    """Hashable bucket token for an effective tags axis (maps bucket by
    content CRC, so two equal maps share a batched slot)."""
    if isinstance(tags, TagMap):
        return ("map", tags.crc32)
    return tags


@dataclasses.dataclass
class SolveRequest:
    id: int
    handle: str
    b: jnp.ndarray
    tol: float
    x0: Optional[jnp.ndarray] = None
    deadline_s: Optional[float] = None  # wall-clock budget from submit()
    t_submit: float = 0.0               # time.monotonic() at intake
    tags: object = None                 # per-request precision axis override


@dataclasses.dataclass
class SolveReport:
    id: int
    handle: str
    iters: int
    relres: float
    converged: bool
    tag: int
    switch_iters: np.ndarray  # (2,)
    est_bytes: int            # modeled byte share of the batch
    batch_size: int           # real requests in the slot it ran in
    # Degradation reporting (DESIGN.md §14): structured health string
    # (robustness.guards.HEALTH_NAMES, or "error" when the slot's solve
    # itself raised), the first guard-trip iteration within the batched
    # run (-1: never), how many bounded tag-3 retries this request
    # consumed, and whether its deadline lapsed before recovery finished.
    health: str = "ok"
    trip_iter: int = -1
    retries: int = 0
    deadline_exceeded: bool = False


@dataclasses.dataclass
class _Operator:
    name: str
    csr: CSR
    gse: "object"     # GSECSR or GSESellC, packed once at registration
    precond: object   # precond object or None
    part: object = None   # PartitionedGSECSR when registered sharded
    wire: str = "exact"   # halo wire format for the sharded path
    plan: object = None   # tuned/explicit KernelPlan attached at register
    tags: object = None   # handle-default precision axis (PR 10):
    #                       None | int | TagMap | "adaptive"

    @property
    def solve_op(self):
        """The operand handed to the batched solvers: the partition when
        sharded (distributed operator path), else the packed matrix."""
        return self.part if self.part is not None else self.gse


class SolverService:
    """Minimal request-batching front-end for the batched stepped solvers.

    ``slots`` is the batch width every bucket is padded to (the serving
    analogue of a fixed decode batch): requests against the same
    (operator, tol) bucket share one batched solve.  ``flush()`` drains
    all pending requests and returns per-request ``SolveReport``s.
    """

    def __init__(self, slots: int = 4,
                 params: P.MonitorParams | None = None,
                 maxiter: int = 5000,
                 guards: GuardParams | None = DEFAULT_GUARDS,
                 max_retries: int = 1):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.slots = slots
        self.params = params or P.MonitorParams.for_cg()
        self.maxiter = maxiter
        self.guards = guards
        self.max_retries = max_retries
        self._ops: Dict[str, _Operator] = {}
        self._pending: List[SolveRequest] = []
        self._ids = itertools.count()
        self._solutions: Dict[int, jnp.ndarray] = {}
        # Registry-backed telemetry (DESIGN.md §16).  ``stats`` keeps the
        # historical dict shape; the gauge tracks the live queue depth and
        # the histograms feed the p50/p95/p99 flush-latency and
        # bytes-per-request numbers ``run.py --obs`` reports.
        self.service_id = str(next(_SERVICE_IDS))
        const = {"service": self.service_id}
        self.stats = OM.stats_view(
            "repro_serve_events_total",
            ("batches", "requests", "padded_cols", "modeled_bytes",
             "retries", "errors", "deadline_exceeded"),
            help="SolverService lifetime event counts by kind.",
            const=const,
        )
        self.queue_depth = OM.REGISTRY.gauge(
            "repro_serve_queue_depth",
            "Requests waiting for the next flush.",
            labelnames=("service",),
        ).labels(**const)
        self.flush_latency = OM.REGISTRY.histogram(
            "repro_serve_flush_latency_seconds",
            "Wall-clock seconds per SolverService.flush call.",
            labelnames=("service",),
        ).labels(**const)
        self.request_bytes = OM.REGISTRY.histogram(
            "repro_serve_request_bytes",
            "Modeled streamed bytes charged to each served request.",
            labelnames=("service",),
            buckets=OM.DEFAULT_BYTE_BUCKETS,
        ).labels(**const)

    # -- registration ------------------------------------------------------

    def register(self, name: str, a: CSR, k: int = 8,
                 precond: str | object | None = None,
                 layout: str = "csr", sharded: bool = False,
                 shards: int | None = None, wire: str = "exact",
                 plan=None, tune: bool = False, tags=None) -> str:
        """Pack ``a`` (and optionally a preconditioner) once; returns the
        handle requests are submitted against.  ``precond`` is ``None``,
        ``"jacobi"``/``"spai0"``, or a ready :mod:`repro.solvers.precond`
        object (Carson-Khan-style setup reuse: one packed preconditioner
        serves every request against the handle).

        ``layout="sell"`` additionally packs the operator into the
        SELL-C-σ sliced layout (``kernels.ops.sell_pack_gsecsr``, cached
        on the packed instance -- DESIGN.md §12): trajectories are
        bit-identical to the ``"csr"`` default, but byte reports charge
        the layout's ACTUAL padded slots instead of nnz only.

        ``sharded=True`` row-shards the packed operator across ``shards``
        devices (default: all visible) and serves every request against
        the handle through the distributed solver path (DESIGN.md §13);
        ``wire`` picks the halo wire format (``"exact"`` f64 halos,
        ``"gse"`` tag-aware compressed halos) and the byte reports add the
        halo wire traffic per iteration.

        ``plan``/``tune`` attach a kernel launch plan to the handle
        (DESIGN.md §15): an explicit :class:`repro.perf.plan.KernelPlan`
        is used as-is; ``tune=True`` resolves one through the persisted
        autotuner (``perf.autotune.get_or_tune`` -- a sweep on the first
        registration of a matrix class, a pure cache hit afterwards).
        The SELL pack then uses the plan's C/σ/lane/bucket parameters;
        solve trajectories stay bit-identical (the stepped solvers decode
        through the packed store, not the launch blocks).

        ``tags`` sets the handle's DEFAULT precision axis (PR 10,
        DESIGN.md §18), overridable per request at ``submit``: an int or
        uniform :class:`~repro.core.tagmap.TagMap` pins the start tag, a
        non-uniform map runs the masked per-group schedule, and
        ``"adaptive"`` serves every request against the handle through
        the data-driven per-group driver
        (:func:`repro.solvers.adaptive.solve_adaptive`)."""
        if name in self._ops:
            raise ValueError(f"handle {name!r} already registered")
        if layout not in ("csr", "sell"):
            raise ValueError(
                f"unknown layout {layout!r}; expected 'csr' or 'sell'"
            )
        if sharded and layout == "sell":
            raise ValueError(
                "sharded=True serves through the row-sharded CSR decode; "
                "the SELL layout is single-device (pick one)"
            )
        if wire not in ("exact", "gse"):
            raise ValueError(
                f"unknown wire mode {wire!r}; expected 'exact' or 'gse'"
            )
        tags = _normalize_service_tags(tags, int(a.shape[0]),
                                       sharded=sharded,
                                       sell=layout == "sell")
        if isinstance(precond, str):
            try:
                precond = _PRECOND_FACTORY[precond](a, k=k)
            except KeyError:
                raise ValueError(
                    f"unknown preconditioner {precond!r}; expected one of "
                    f"{sorted(_PRECOND_FACTORY)}"
                ) from None
        gse = pack_csr(a, k=k)
        if tune and plan is None:
            from repro.perf import autotune

            plan, _, _ = autotune.get_or_tune(
                gse, tag=1, layout="sell" if layout == "sell" else "ell")
        part = None
        if sharded:
            import jax

            from repro.distributed.partition import partition_gsecsr

            part = partition_gsecsr(gse, shards or jax.device_count())
        if layout == "sell":
            from repro.kernels.ops import sell_pack_gsecsr

            gse = sell_pack_gsecsr(gse, plan=plan)
        self._ops[name] = _Operator(
            name=name, csr=a, gse=gse, precond=precond, part=part,
            wire=wire, plan=plan, tags=tags
        )
        return name

    # -- request intake ----------------------------------------------------

    def submit(self, handle: str, b, tol: float = 1e-8, x0=None,
               deadline_s: float | None = None, tags=None) -> int:
        """Queue one solve request; returns its request id.

        ``tags`` overrides the handle's default precision axis for this
        request only (same values as ``register``; requests bucket by
        their EFFECTIVE axis, so mixed-tags traffic against one handle
        never shares a batched slot across axes).

        Intake validation (DESIGN.md §14): ``b`` must match the handle's
        dimension, be a floating dtype, and be entirely finite -- a NaN/Inf
        right-hand side can never produce a meaningful solution, so it is
        rejected HERE with ``ValueError`` instead of burning a batch slot
        and coming back flagged ``nonfinite``.  ``deadline_s`` is a
        wall-clock budget measured from submission; a lapsed deadline
        suppresses tag-3 retry recovery for this request (the degraded
        report still carries whatever the batched pass produced)."""
        op = self._ops.get(handle)
        if op is None:
            raise KeyError(f"unknown handle {handle!r}")
        b = jnp.asarray(b)
        if b.ndim == 2 and b.shape[1] == 1:
            b = b[:, 0]
        if b.ndim != 1 or b.shape[0] != op.csr.shape[0]:
            raise ValueError(
                f"b must be ({op.csr.shape[0]},) or ({op.csr.shape[0]}, 1) "
                f"for handle {handle!r}; got {tuple(b.shape)}"
            )
        if not jnp.issubdtype(b.dtype, jnp.floating):
            raise ValueError(
                f"b must have a floating dtype for handle {handle!r}; "
                f"got {b.dtype}"
            )
        if not bool(jnp.isfinite(b).all()):
            raise ValueError(
                f"b contains non-finite entries (handle {handle!r}); "
                "rejected at intake"
            )
        if x0 is not None:
            x0 = jnp.asarray(x0)
            if x0.ndim == 2 and x0.shape[1] == 1:
                x0 = x0[:, 0]  # same (n, 1) normalization as b
            if x0.shape != b.shape:
                raise ValueError(
                    f"x0 shape {tuple(x0.shape)} != b shape {tuple(b.shape)}"
                )
            if not bool(jnp.isfinite(x0).all()):
                raise ValueError(
                    f"x0 contains non-finite entries (handle {handle!r}); "
                    "rejected at intake"
                )
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        tags = _normalize_service_tags(
            tags, int(op.csr.shape[0]), sharded=op.part is not None,
            sell=isinstance(op.gse, GSESellC))
        rid = next(self._ids)
        self._pending.append(SolveRequest(rid, handle, b, float(tol), x0,
                                          deadline_s=deadline_s,
                                          t_submit=time.monotonic(),
                                          tags=tags))
        self.queue_depth.set(len(self._pending))
        return rid

    # -- batch execution ---------------------------------------------------

    def flush(self) -> Dict[int, SolveReport]:
        """Drain pending requests: bucket by (handle, tol), pad to the slot
        width, run the batched stepped solver, report per request.

        Solutions are retained only until the NEXT flush (claim them with
        :meth:`solution`), so a long-running service that only reads the
        reports does not accumulate solved vectors without bound.

        Degradation contract (DESIGN.md §14): ``flush`` never raises out
        of a slot -- a slot whose solve itself throws degrades to error
        reports (``health="error"``, not converged, no solution) for its
        requests, and every returned solution is either finite or flagged
        by a non-ok health."""
        t0 = time.perf_counter()
        self._solutions.clear()
        buckets: Dict[tuple, tuple] = {}
        for req in self._pending:
            # The EFFECTIVE precision axis (request override, else the
            # handle default) is part of the bucket: one batched slot,
            # one axis.
            eff = req.tags if req.tags is not None \
                else self._ops[req.handle].tags
            key = (req.handle, req.tol, _tags_token(eff))
            buckets.setdefault(key, (eff, []))[1].append(req)
        drained = len(self._pending)
        self._pending = []
        self.queue_depth.set(0)

        reports: Dict[int, SolveReport] = {}
        with OT.span("serve.flush", service=self.service_id,
                     requests=drained) as attrs:
            for (handle, tol, _tok), (eff, reqs) in buckets.items():
                op = self._ops[handle]
                for i in range(0, len(reqs), self.slots):
                    chunk = reqs[i:i + self.slots]
                    try:
                        reports.update(
                            self._run_slot(op, tol, chunk, tags=eff))
                    except Exception:  # degraded, never propagated
                        self.stats["errors"] += 1
                        for req in chunk:
                            self._solutions.pop(req.id, None)
                            reports[req.id] = SolveReport(
                                id=req.id, handle=op.name, iters=0,
                                relres=float("inf"), converged=False, tag=0,
                                switch_iters=np.full(2, -1, np.int64),
                                est_bytes=0, batch_size=len(chunk),
                                health="error",
                            )
            attrs["bytes"] = sum(r.est_bytes for r in reports.values())
        for rep in reports.values():
            self.request_bytes.observe(rep.est_bytes)
        self.flush_latency.observe(time.perf_counter() - t0)
        return reports

    def _run_slot(self, op: _Operator, tol: float,
                  reqs: List[SolveRequest],
                  tags=None) -> Dict[int, SolveReport]:
        if tags == "adaptive":
            return self._run_adaptive(op, tol, reqs)
        n = op.csr.shape[0]
        nrhs = self.slots
        pad = nrhs - len(reqs)
        zero = jnp.zeros((n,), reqs[0].b.dtype)
        cols = [r.b for r in reqs] + [zero] * pad
        b = jnp.stack(cols, axis=1)
        x0 = None
        if any(r.x0 is not None for r in reqs):
            x0 = jnp.stack(
                [r.x0 if r.x0 is not None else zero for r in reqs]
                + [zero] * pad,
                axis=1,
            )
        if op.precond is not None:
            res = solve_pcg_batched(op.solve_op, b, op.precond, x0=x0,
                                    tol=tol, maxiter=self.maxiter,
                                    params=self.params, wire=op.wire,
                                    guards=self.guards, tags=tags)
        else:
            res = solve_cg_batched(op.solve_op, b, x0=x0, tol=tol,
                                   maxiter=self.maxiter, params=self.params,
                                   wire=op.wire, guards=self.guards,
                                   tags=tags)

        iters = np.asarray(res.iters)
        sw = np.asarray(res.switch_iters)
        nreal = len(reqs)
        health = np.broadcast_to(
            np.asarray(getattr(res, "health", 0)), iters.shape
        ).astype(np.int64)
        trip = np.broadcast_to(
            np.asarray(getattr(res, "trip_iter", -1)), iters.shape
        ).astype(np.int64)
        shares, total_bytes = self._byte_shares(op, iters, sw, tags=tags)
        self.stats["batches"] += 1
        self.stats["requests"] += nreal
        self.stats["padded_cols"] += pad
        self.stats["modeled_bytes"] += total_bytes

        out = {}
        for j, req in enumerate(reqs):
            x = res.x[:, j]
            it_j = int(iters[j])
            relres_j = float(res.relres[j])
            conv_j = bool(res.converged[j])
            tag_j = int(res.tag[j])
            sw_j = sw[j]
            bytes_j = int(shares[j])
            h_j = int(health[j])
            trip_j = int(trip[j])
            retries = 0
            deadline_hit = False
            x_finite = bool(jnp.isfinite(jnp.vdot(x, x)))
            # Degraded column: bounded single-RHS retries at tag 3 (the
            # exact path -- the strongest rung the escalation ladder has).
            # A lapsed deadline suppresses retries; the degraded report
            # still ships whatever the batched pass produced, flagged.
            while (not conv_j or not x_finite) and retries < self.max_retries:
                if req.deadline_s is not None and \
                        time.monotonic() - req.t_submit > req.deadline_s:
                    deadline_hit = True
                    self.stats["deadline_exceeded"] += 1
                    break
                retries += 1
                self.stats["retries"] += 1
                warm = x if x_finite else req.x0
                if op.precond is not None:
                    r2 = solve_pcg(op.solve_op, req.b, op.precond, x0=warm,
                                   tol=tol, maxiter=self.maxiter,
                                   params=self.params, wire=op.wire,
                                   guards=self.guards, init_tag=3)
                else:
                    r2 = solve_cg(op.solve_op, req.b, x0=warm, tol=tol,
                                  maxiter=self.maxiter, params=self.params,
                                  wire=op.wire, guards=self.guards,
                                  init_tag=3)
                rx_finite = bool(jnp.isfinite(jnp.vdot(r2.x, r2.x)))
                r2_trip = int(getattr(r2, "trip_iter", -1))
                if trip_j < 0 and r2_trip >= 0:
                    trip_j = it_j + r2_trip
                it_j += int(r2.iters)
                relres_j = float(r2.relres)
                conv_j = bool(r2.converged)
                tag_j = int(r2.tag)
                h_j = int(getattr(r2, "health", HEALTH_OK))
                if rx_finite:
                    x = r2.x
                x_finite = x_finite or rx_finite
                sh2, tot2 = self._byte_shares(
                    op, np.asarray([int(r2.iters)]),
                    np.asarray(r2.switch_iters).reshape(1, -1),
                )
                bytes_j += int(sh2[0])
                self.stats["modeled_bytes"] += tot2
            # Belt and braces: a non-finite solution NEVER leaves the
            # service unflagged, whatever the solver reported.
            if not x_finite and h_j == HEALTH_OK:
                h_j = HEALTH_NONFINITE
                conv_j = False
            self._solutions[req.id] = x
            out[req.id] = SolveReport(
                id=req.id,
                handle=op.name,
                iters=it_j,
                relres=relres_j,
                converged=conv_j,
                tag=tag_j,
                switch_iters=sw_j,
                est_bytes=bytes_j,
                batch_size=nreal,
                health=health_name(h_j),
                trip_iter=trip_j,
                retries=retries,
                deadline_exceeded=deadline_hit,
            )
        return out

    def _run_adaptive(self, op: _Operator, tol: float,
                      reqs: List[SolveRequest]) -> Dict[int, SolveReport]:
        """``tags="adaptive"`` dispatch: the data-driven per-group driver
        is a host loop over single-RHS segments (DESIGN.md §18), so each
        request runs its own solve -- no slot sharing, and ``est_bytes``
        is the driver's OWN blended account (masked matrix stream plus
        the billed true-residual checks, ``AdaptiveResult.spmv_bytes``)
        instead of the column-share model.  ``relres`` reports the TRUE
        tag-3 residual -- the number the adaptive stop is gated on.
        Degraded requests get the same bounded tag-3 retry as the
        batched path."""
        from repro.solvers.adaptive import solve_adaptive

        clock = getattr(self, "clock", time.monotonic)
        out = {}
        self.stats["batches"] += 1
        self.stats["requests"] += len(reqs)
        for req in reqs:
            res = solve_adaptive(op.gse, req.b, precond=op.precond,
                                 x0=req.x0, tol=tol, maxiter=self.maxiter,
                                 params=self.params)
            x = res.x
            it_j = int(res.iters)
            relres_j = float(res.true_relres)
            conv_j = bool(res.converged)
            tag_j = int(res.tagmap.max_tag)
            bytes_j = int(res.spmv_bytes)
            h_j = HEALTH_OK
            retries = 0
            deadline_hit = False
            x_finite = bool(jnp.isfinite(jnp.vdot(x, x)))
            self.stats["modeled_bytes"] += bytes_j
            while (not conv_j or not x_finite) and retries < self.max_retries:
                if req.deadline_s is not None and \
                        clock() - req.t_submit > req.deadline_s:
                    deadline_hit = True
                    self.stats["deadline_exceeded"] += 1
                    break
                retries += 1
                self.stats["retries"] += 1
                warm = x if x_finite else req.x0
                if op.precond is not None:
                    r2 = solve_pcg(op.gse, req.b, op.precond, x0=warm,
                                   tol=tol, maxiter=self.maxiter,
                                   params=self.params, guards=self.guards,
                                   init_tag=3)
                else:
                    r2 = solve_cg(op.gse, req.b, x0=warm, tol=tol,
                                  maxiter=self.maxiter, params=self.params,
                                  guards=self.guards, init_tag=3)
                rx_finite = bool(jnp.isfinite(jnp.vdot(r2.x, r2.x)))
                it_j += int(r2.iters)
                relres_j = float(r2.relres)
                conv_j = bool(r2.converged)
                tag_j = int(r2.tag)
                h_j = int(getattr(r2, "health", HEALTH_OK))
                if rx_finite:
                    x = r2.x
                x_finite = x_finite or rx_finite
                sh2, tot2 = self._byte_shares(
                    op, np.asarray([int(r2.iters)]),
                    np.asarray(r2.switch_iters).reshape(1, -1),
                )
                bytes_j += int(sh2[0])
                self.stats["modeled_bytes"] += tot2
            if not x_finite and h_j == HEALTH_OK:
                h_j = HEALTH_NONFINITE
                conv_j = False
            self._solutions[req.id] = x
            out[req.id] = SolveReport(
                id=req.id,
                handle=op.name,
                iters=it_j,
                relres=relres_j,
                converged=conv_j,
                tag=tag_j,
                switch_iters=np.full(2, -1, np.int64),
                est_bytes=bytes_j,
                batch_size=len(reqs),
                health=health_name(h_j),
                trip_iter=-1,
                retries=retries,
                deadline_exceeded=deadline_hit,
            )
        return out

    def solution(self, request_id: int) -> jnp.ndarray:
        """The solved ``x`` for a flushed request (pop to free memory)."""
        try:
            return self._solutions.pop(request_id)
        except KeyError:
            raise KeyError(
                f"no flushed solution for request {request_id!r}"
            ) from None

    def _byte_shares(self, op: _Operator, iters, sw, tags=None):
        """One walk of the per-iteration byte model: returns the per-column
        shares AND their sum, which is exactly ``batched_run_bytes`` (each
        iteration adds ``iteration_stream_bytes(..., nrhs=n_active)``
        split evenly among the columns sharing the streaming pass).

        ``tags`` is the slot's effective precision axis: a non-uniform
        :class:`TagMap` charges every live iteration the BLENDED
        per-group stream (the map is pinned -- no switch schedule); an
        int floors the monitor's switch-schedule tag (the batch started
        there, not at tag 1)."""
        nrhs = iters.shape[0]
        shares = np.zeros(nrhs, np.float64)
        tm = tags if isinstance(tags, TagMap) else None
        floor = int(tags) if isinstance(tags, (int, np.integer)) else 1
        for it in range(int(iters.max(initial=0))):
            col_tags = column_tags_at(iters, sw, it)
            live = np.nonzero(col_tags > 0)[0]
            if live.size == 0:
                continue
            if tm is not None:
                tot = iteration_stream_bytes(op.gse, tm, op.precond,
                                             nrhs=live.size)
                shares[live] += tot / live.size
                continue
            tag = max(int(col_tags.max()), floor)
            if op.part is not None:
                # Sharded handle: the canonical distributed account --
                # single-device matrix stream redistributed + per-column
                # halo wire traffic + per-extra-column vector streams.
                tot = op.part.iteration_stream_bytes(tag, op.wire,
                                                     nrhs=live.size)
                if op.precond is not None:
                    tot += op.precond.bytes_touched(tag)
            else:
                tot = iteration_stream_bytes(op.gse, tag, op.precond,
                                             nrhs=live.size)
            # The iteration's batch total divides evenly among the
            # columns sharing the streaming pass.
            shares[live] += tot / live.size
        return np.rint(shares).astype(np.int64), int(round(shares.sum()))


def main():
    import argparse
    import time

    from repro.sparse import generators as G
    from repro.sparse.spmv import spmv

    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--n", type=int, default=24, help="Poisson grid side")
    ap.add_argument("--precond", default="none",
                    choices=["none", "jacobi", "spai0"])
    ap.add_argument("--layout", default="csr", choices=["csr", "sell"],
                    help="operator pack: 'sell' rides the SELL-C-sigma "
                         "sliced layout (padding-honest byte reports)")
    ap.add_argument("--shards", type=int, default=0,
                    help="> 0: row-shard the operator and serve through "
                         "the distributed path (needs that many devices; "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N on CPU)")
    ap.add_argument("--wire", default="exact", choices=["exact", "gse"],
                    help="halo wire format for --shards (DESIGN.md "
                         "section 13)")
    ap.add_argument("--tol", type=float, default=1e-8)
    args = ap.parse_args()

    a = G.poisson2d(args.n)
    params = P.MonitorParams(t=40, l=60, m=30, rsd_limit=0.5,
                             reldec_limit=0.45)
    svc = SolverService(slots=args.slots, params=params, maxiter=20000)
    svc.register("poisson", a, k=8,
                 precond=None if args.precond == "none" else args.precond,
                 layout=args.layout, sharded=args.shards > 0,
                 shards=args.shards or None, wire=args.wire)

    rng = np.random.default_rng(0)
    ids = []
    for _ in range(args.requests):
        b = spmv(a, jnp.asarray(rng.normal(size=a.shape[1])))
        ids.append(svc.submit("poisson", b, tol=args.tol))

    t0 = time.time()
    reports = svc.flush()
    dt = time.time() - t0
    for rid in ids:
        r = reports[rid]
        print(
            f"req {r.id}: iters={r.iters} relres={r.relres:.2e} "
            f"converged={r.converged} tag={r.tag} "
            f"switches={r.switch_iters.tolist()} "
            f"est_bytes={r.est_bytes} batch={r.batch_size}/{args.slots} "
            f"health={r.health}"
        )
    s = svc.stats
    print(
        f"served {s['requests']} requests in {s['batches']} batches "
        f"({s['padded_cols']} padded cols, "
        f"{s['modeled_bytes'] / 1e6:.2f} MB modeled matrix+vector stream) "
        f"in {dt:.2f}s"
    )


if __name__ == "__main__":
    import jax

    jax.config.update("jax_enable_x64", True)
    main()
