"""In-loop solver guardrails and tag-escalation recovery (DESIGN.md §14).

Every Krylov loop in the repo is a ``jax.lax.while_loop``; a tag-1
breakdown used to mean one of two silent failure modes:

  * ``p.Ap <= 0`` (indefinite low-tag perturbation) -- alpha's
    divide-guard kicks in and the loop spins to ``maxiter`` on garbage;
  * a NaN residual -- ``NaN > tol`` is False, so the loop EXITS EARLY and
    returns an unflagged non-finite x that looks "converged by maxiter".

The guard runs alongside the update (never inside it -- the update
arithmetic is bit-identical with guards on or off, which is what keeps
the fused/unfused, SELL-vs-CSR and 1-shard-vs-``solve_cg`` contracts
intact).  Each iteration classifies the new state into one of five
health codes and the loop condition adds ``health == OK``, so a tripped
guard stops the loop at the trip iteration instead of burning budget.

Recovery is a HOST-side driver (:func:`run_with_recovery`): the loops
also carry the last known-finite x as a checkpoint; on a trip at
tag < 3 the driver rolls back to the checkpoint, promotes the tag
(rebuilding the monitor window from scratch, so NaNs can never poison
the C1/C2 metrics), records the promotion into ``switch_iters`` at the
GLOBAL iteration (fig89's byte model splits the trajectory by those
switch points -- recovery stays byte-accounted), and resumes with the
remaining budget.  The terminal rung is the exact tag-3 path: the same
resume machinery ``_finish_with_correction`` uses.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = [
    "HEALTH_OK",
    "HEALTH_BREAKDOWN",
    "HEALTH_DIVERGED",
    "HEALTH_NONFINITE",
    "HEALTH_STALLED",
    "HEALTH_NAMES",
    "health_name",
    "GuardParams",
    "DEFAULT_GUARDS",
    "guard_init",
    "guard_step",
    "finalize_health",
    "run_with_recovery",
    "run_with_recovery_map",
]

# Health codes, carried as int32 scalars through the jitted loops so the
# structured status survives jit/shard_map boundaries.  Order encodes
# severity: when several conditions fire in one iteration the LARGEST
# diagnosable code wins (nonfinite > diverged/breakdown > stalled).
HEALTH_OK = 0
HEALTH_BREAKDOWN = 1   # p.Ap <= 0 (or z.r < 0 under PCG, lucky-zero GMRES)
HEALTH_DIVERGED = 2    # relres blew past div_factor * best-seen
HEALTH_NONFINITE = 3   # NaN/Inf in the residual recurrence
HEALTH_STALLED = 4     # no new best residual for stall_window iterations

HEALTH_NAMES = ("ok", "breakdown", "diverged", "nonfinite", "stalled")


def health_name(code) -> str:
    """Human-readable name for a health code (accepts traced/np scalars)."""
    i = int(code)
    if 0 <= i < len(HEALTH_NAMES):
        return HEALTH_NAMES[i]
    return f"unknown({i})"


@dataclasses.dataclass(frozen=True)
class GuardParams:
    """Static (hashable) guard thresholds -- a jit static arg, like
    ``MonitorParams``.

    ``div_factor``: trip DIVERGED when the recursive relative residual
    exceeds ``div_factor *`` the best residual seen so far.  CG residuals
    legitimately oscillate orders of magnitude on ill-conditioned
    problems, so this is deliberately loose (1e4).

    ``stall_window``: trip STALLED after this many iterations without a
    new best residual.  Must comfortably exceed the precision monitor's
    decision window (``MonitorParams.t``/``l``), otherwise the guard
    steals breakdowns the monitor would have resolved by stepping the
    tag on its own.
    """
    div_factor: float = 1e4
    stall_window: int = 1000


DEFAULT_GUARDS = GuardParams()


def guard_init(relres0):
    """Guard state for a loop whose initial relative residual is
    ``relres0``.

    A non-finite INITIAL residual (b or x0 poisoned, or an operator that
    NaNs at the starting tag) trips immediately with ``trip = 0``: the
    while_loop would otherwise exit before iteration 0 (``NaN > tol`` is
    False) and report an unflagged "converged" garbage x.
    """
    relres0 = jnp.asarray(relres0)
    finite = jnp.isfinite(relres0)
    big = jnp.asarray(jnp.finfo(relres0.dtype).max, relres0.dtype)
    return {
        "health": jnp.where(finite, HEALTH_OK, HEALTH_NONFINITE).astype(jnp.int32),
        "best": jnp.where(finite, relres0, big),
        "best_it": jnp.int32(0),
        "trip": jnp.where(finite, -1, 0).astype(jnp.int32),
    }


def guard_step(g, it, relres, params: GuardParams, *, denom=None,
               breakdown=False, finite_aux=()):
    """One guard update, evaluated AFTER the iteration's arithmetic.

    ``it`` is the (0-based) iteration that just ran; ``relres`` its new
    recursive relative residual.  ``denom`` (optional) is the curvature
    ``p.Ap`` -- ``denom <= 0`` is the classic CG breakdown.  ``breakdown``
    folds in extra solver-specific breakdown predicates (e.g. ``z.r < 0``
    under PCG).  ``finite_aux`` lists extra scalars that must stay finite
    (recurrence coefficients whose NaN may precede the residual's).

    Only the FIRST trip is latched: health and trip-iteration freeze once
    set, so the loop condition (``health == OK``) exits on the next check
    and the report names the iteration that actually failed.
    """
    relres = jnp.asarray(relres)
    finite = jnp.isfinite(relres)
    for a in finite_aux:
        finite = finite & jnp.isfinite(jnp.asarray(a))

    code = jnp.where(
        (it - g["best_it"]) >= params.stall_window,
        HEALTH_STALLED, HEALTH_OK,
    )
    code = jnp.where(relres > params.div_factor * g["best"],
                     HEALTH_DIVERGED, code)
    bad = jnp.asarray(breakdown)
    if denom is not None:
        denom = jnp.asarray(denom)
        bad = bad | (denom <= 0)
        finite = finite & jnp.isfinite(denom)
    code = jnp.where(bad, HEALTH_BREAKDOWN, code)
    code = jnp.where(finite, code, HEALTH_NONFINITE).astype(jnp.int32)

    was_ok = g["health"] == HEALTH_OK
    health = jnp.where(was_ok, code, g["health"])
    trip = jnp.where(was_ok & (code != HEALTH_OK),
                     jnp.asarray(it, jnp.int32), g["trip"])
    improved = finite & (relres < g["best"])
    return {
        "health": health,
        "best": jnp.where(improved, relres, g["best"]),
        "best_it": jnp.where(improved, jnp.asarray(it, jnp.int32),
                             g["best_it"]),
        "trip": trip,
    }


def finalize_health(g, converged, relres, x_finite=True):
    """Map the end-of-loop state to the reported ``(health, trip_iter)``.

    Convergence overrides everything: a ``denom == 0`` on the very
    iteration that reached tol is exact convergence, not breakdown (the
    alpha divide-guard already handles the arithmetic).  An unconverged
    clean exit is maxiter exhaustion -> STALLED with ``trip = -1`` (no
    in-loop trip; recovery keys off ``trip >= 0`` so plain budget
    exhaustion is reported, not "recovered").  ``x_finite`` folds in a
    final finiteness certificate on the solution vector for solvers
    (GMRES) whose x is assembled after the guarded loop.

    ``g`` may be ``None`` (guards disabled): the classification is then
    purely post-hoc -- converged / nonfinite / stalled.
    """
    relres = jnp.asarray(relres)
    ok_exit = jnp.isfinite(relres) & jnp.asarray(x_finite)
    base = jnp.where(ok_exit, HEALTH_STALLED, HEALTH_NONFINITE)
    trip = jnp.int32(-1)
    if g is not None:
        base = jnp.where(g["health"] != HEALTH_OK, g["health"], base)
        trip = g["trip"]
    health = jnp.where(converged, HEALTH_OK, base).astype(jnp.int32)
    trip = jnp.where(converged, jnp.int32(-1), trip)
    return health, trip


def run_with_recovery(run, x0, maxiter: int, init_tag: int = 1,
                      recover: bool = True, max_tag: int = 3):
    """Host-side escalation driver around a guarded solver run.

    ``run(x_start, budget, tag)`` must execute the solver from
    ``x_start`` with at most ``budget`` iterations, the monitor starting
    at ``tag``, and return ``(res, ckpt)`` where ``res`` carries
    ``health`` / ``trip_iter`` / ``iters`` / ``switch_iters`` and
    ``ckpt`` is the last known-finite iterate (== ``res.x`` on a clean
    run).

    On a trip at tag < ``max_tag`` the driver restarts from ``ckpt`` at
    the next tag with the REMAINING budget and a fresh monitor (the
    paper's window metrics are rebuilt from scratch -- a NaN residual
    from the failed segment can never poison C1/C2).  Each escalation is
    written into ``switch_iters`` at the global iteration it happened,
    so ``iteration_stream_bytes``/fig89 charge the pre-escalation
    segment at the cheap tag and the resumed segment at the promoted
    tag -- recovery stays byte-accounted.  The final rung is tag 3: the
    exact path, same machinery ``_finish_with_correction`` resumes on.

    The merged result reports cumulative ``iters``, the FIRST global
    trip iteration (``health == ok`` with ``trip_iter >= 0`` therefore
    reads "tripped, recovered"), and the last run's health otherwise.
    """
    res, ckpt = run(x0, maxiter, init_tag)
    if not recover:
        return res
    health = int(res.health)
    trip = int(res.trip_iter)
    if health == HEALTH_OK or trip < 0:
        return res

    total = int(res.iters)
    first_trip = trip
    sw = np.asarray(res.switch_iters, dtype=np.int64).copy()
    tag = max(int(res.tag), init_tag)
    while health != HEALTH_OK and trip >= 0 and tag < max_tag:
        tag += 1
        # The escalation IS a tag switch: record it at the global
        # iteration so the byte model bills segments honestly.
        if sw[tag - 2] < 0:
            sw[tag - 2] = total
        budget = max(maxiter - total, 1)
        res, ckpt = run(ckpt, budget, tag)
        inner_sw = np.asarray(res.switch_iters, dtype=np.int64)
        for s in range(sw.shape[0]):
            if inner_sw[s] >= 0 and sw[s] < 0:
                sw[s] = total + inner_sw[s]
        total += int(res.iters)
        health = int(res.health)
        trip = int(res.trip_iter)
        tag = max(int(res.tag), tag)
    return res._replace(
        iters=jnp.asarray(total, jnp.int32),
        switch_iters=jnp.asarray(sw, jnp.int32),
        trip_iter=jnp.asarray(first_trip, jnp.int32),
    )


def run_with_recovery_map(run, x0, maxiter: int, tm, recover: bool = True):
    """Per-group twin of :func:`run_with_recovery` (PR 10, DESIGN.md §18).

    ``run(x_start, budget, floor)`` must execute the solver with the
    static :class:`~repro.core.tagmap.TagMap` FLOORED at ``floor``
    (``TagMap.floored``: every group raised to at least the floor) and
    return ``(res, ckpt)`` like the scalar driver's ``run``.

    A trip escalates the floor one rung instead of the whole operator:
    only the groups BELOW the floor promote -- the already-promoted
    high-sensitivity groups keep their tags and the recovery cost is the
    cheapest map that is one rung safer everywhere.  The final rung
    (floor 3) is the uniform exact path, the same termination guarantee
    as the scalar ladder.  Each escalation is billed into
    ``switch_iters`` at its global iteration; inner runs never step
    in-loop (the monitor is pinned at the map's max tag), so there is no
    inner switch record to merge.
    """
    floor = tm.min_tag
    res, ckpt = run(x0, maxiter, floor)
    if not recover:
        return res
    health = int(res.health)
    trip = int(res.trip_iter)
    if health == HEALTH_OK or trip < 0:
        return res

    total = int(res.iters)
    first_trip = trip
    sw = np.asarray(res.switch_iters, dtype=np.int64).copy()
    while health != HEALTH_OK and trip >= 0 and floor < 3:
        floor += 1
        if sw[floor - 2] < 0:
            sw[floor - 2] = total
        budget = max(maxiter - total, 1)
        res, ckpt = run(ckpt, budget, floor)
        total += int(res.iters)
        health = int(res.health)
        trip = int(res.trip_iter)
    return res._replace(
        iters=jnp.asarray(total, jnp.int32),
        switch_iters=jnp.asarray(sw, jnp.int32),
        trip_iter=jnp.asarray(first_trip, jnp.int32),
    )
