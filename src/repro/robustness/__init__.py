"""Robustness subsystem: solver guardrails, tag-escalation recovery, and
fault injection (DESIGN.md §14).

The paper's format makes precision promotion nearly free -- one packed
copy readable at tags 1/2/3 -- but the solver stack was fast-when-healthy
only: a tag-1 breakdown (p.Ap <= 0, NaN residual, stagnation) either
burned the full ``maxiter`` budget or returned unflagged garbage.  This
package supplies:

  * :mod:`repro.robustness.guards` -- in-loop breakdown/divergence/
    non-finite/stall detection for every solver loop, the structured
    ``health`` status carried by every ``*Result``, and the host-side
    tag-escalation recovery driver (roll back to the last finite
    checkpoint, promote the tag, resume -- ultimately on the exact tag-3
    path);
  * :mod:`repro.robustness.faults` -- deterministic, seeded bit-flip
    injection into GSE pack segments / shared-exponent tables / halo wire
    buffers, segment checksums for silent-corruption detection, and
    tag-dependent fault operators that break ONLY at low tags (the
    recovery path's test harness).
"""
from repro.robustness.guards import (
    DEFAULT_GUARDS,
    GuardParams,
    HEALTH_BREAKDOWN,
    HEALTH_DIVERGED,
    HEALTH_NONFINITE,
    HEALTH_OK,
    HEALTH_STALLED,
    finalize_health,
    guard_init,
    guard_step,
    health_name,
    run_with_recovery,
)

__all__ = [
    "DEFAULT_GUARDS",
    "GuardParams",
    "HEALTH_BREAKDOWN",
    "HEALTH_DIVERGED",
    "HEALTH_NONFINITE",
    "HEALTH_OK",
    "HEALTH_STALLED",
    "finalize_health",
    "guard_init",
    "guard_step",
    "health_name",
    "run_with_recovery",
]
