"""Deterministic fault injection for the GSE stack (DESIGN.md §14).

The fault model is silent data corruption in the places the paper's
format actually keeps bits:

  * the packed GSE segment arrays of a :class:`~repro.sparse.csr.GSECSR`
    (head / tail1 / tail2 / colpak) and its shared-exponent ``table``;
  * the halo-exchange wire buffers of :mod:`repro.distributed.wire`
    (heads, tails, tables crossing the interconnect);
  * the memoized packed-operand entries of ``kernels/ops._cached_pack``
    (host memory in a long-lived service process).

Everything here is seeded and reproducible: ``numpy.random.default_rng``
picks (element, bit) pairs and the corruption is a plain XOR, so a CI
smoke run injects the SAME faults every time and the detection-rate gate
in ``run.py --robust`` is deterministic.

A second family of faults lives at the OPERATOR level:
:func:`make_tag_fault_operator` wraps an operator so it misbehaves only
at tags <= ``fail_tag`` (indefinite or NaN-producing) and is exact above
-- the canonical recoverable low-tag breakdown that the guard +
tag-escalation machinery (``robustness/guards.py``) must detect and
solve through.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.csr import GSECSR

__all__ = [
    "bitflip_array",
    "corrupt_gsecsr",
    "corrupt_pack_cache",
    "gsecsr_checksums",
    "verify_gsecsr",
    "make_wire_fault",
    "make_tag_fault_operator",
]

# GSECSR segments that injection may target (all fixed-width unsigned /
# int storage, so a bit-flip is well defined and silent by construction).
GSECSR_SEGMENTS = ("head", "tail1", "tail2", "colpak", "table")


def bitflip_array(arr, seed: int, nflips: int = 1):
    """Return a copy of ``arr`` with ``nflips`` seeded single-bit flips.

    Works on any fixed-width dtype: floats are reinterpreted as the
    same-width unsigned integer, flipped, and reinterpreted back --
    exactly the "cosmic ray" model (one storage bit inverted, no
    arithmetic involved).  Returns the same array type it was given
    (numpy in -> numpy out, jax in -> jax out).
    """
    was_jax = isinstance(arr, jax.Array)
    a = np.array(arr)  # host copy, always writable
    if a.size == 0 or nflips <= 0:
        return jnp.asarray(a) if was_jax else a
    width = a.dtype.itemsize * 8
    udtype = np.dtype(f"uint{width}")
    view = a.view(udtype).reshape(-1)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, view.size, size=nflips)
    bit = rng.integers(0, width, size=nflips)
    for i, b in zip(idx, bit):
        view[i] ^= udtype.type(1) << udtype.type(b)
    return jnp.asarray(a) if was_jax else a


def corrupt_gsecsr(a: GSECSR, target: str, seed: int,
                   nflips: int = 1) -> GSECSR:
    """A NEW GSECSR with seeded bit-flips in one segment.

    ``target`` is one of :data:`GSECSR_SEGMENTS`.  The original operand is
    untouched (dataclass copy) -- a test can solve with both and compare.
    Note a ``table`` flip is the high-leverage fault: one shared exponent
    scales a whole group of values (the paper's G parameter), so a single
    bit there can shift every member by powers of two.
    """
    if target not in GSECSR_SEGMENTS:
        raise ValueError(
            f"target must be one of {GSECSR_SEGMENTS}, got {target!r}")
    return dataclasses.replace(
        a, **{target: bitflip_array(getattr(a, target), seed, nflips)}
    )


def gsecsr_checksums(a: GSECSR) -> dict:
    """CRC32 per packed segment -- the reference for :func:`verify_gsecsr`."""
    out = {}
    for name in GSECSR_SEGMENTS:
        seg = np.ascontiguousarray(np.asarray(getattr(a, name)))
        out[name] = zlib.crc32(seg.tobytes())
    return out


def verify_gsecsr(a: GSECSR, ref: dict) -> list:
    """Names of segments whose CRC32 no longer matches ``ref`` (empty =
    intact)."""
    now = gsecsr_checksums(a)
    return [name for name in ref if now.get(name) != ref[name]]


def corrupt_pack_cache(a, key=None, seed: int = 0, nflips: int = 1) -> bool:
    """Silently corrupt a memoized ``_cached_pack`` entry on operator ``a``.

    Swaps bit-flipped copies of the entry's arrays into the cache while
    KEEPING the stored checksum -- modeling host-memory corruption that
    happened after the pack was built.  The next ``_cached_pack`` hit must
    detect the mismatch and repack (``PACK_STATS['corrupt']``).  Returns
    True if an entry was corrupted (False: cache empty / key absent).
    """
    cache = a.__dict__.get("_pack_cache")
    if not cache:
        return False
    if key is None:
        key = next(iter(cache))
    if key not in cache:
        return False
    entry, ck = cache[key]
    leaves, treedef = jax.tree_util.tree_flatten(entry)
    if not leaves:
        return False
    rng = np.random.default_rng(seed)
    which = int(rng.integers(0, len(leaves)))
    leaves[which] = bitflip_array(leaves[which], seed + 1, nflips)
    cache[key] = (jax.tree_util.tree_unflatten(treedef, leaves), ck)
    return True


def make_wire_fault(target: str, seed: int, nflips: int = 1) -> Callable:
    """A wire-fault hook for ``distributed.wire.set_wire_fault``.

    ``target`` names the payload to corrupt (``"head"``, ``"tail1"``,
    ``"table"``, or ``"raw"`` for the exact-wire f64 buffer).  The hook
    receives ``(name, arr)`` for each buffer about to cross the wire
    (AFTER the sender's checksum was computed) and XORs seeded bit
    positions into the matching one -- in-trace, so it works inside
    shard_map.  Flip positions are drawn on the host at hook-build time:
    deterministic per (seed, target).
    """
    def hook(name: str, arr: jnp.ndarray) -> jnp.ndarray:
        if name != target:
            return arr
        if jnp.issubdtype(arr.dtype, jnp.floating):
            width = arr.dtype.itemsize * 8
            udtype = jnp.dtype(f"uint{width}")
            bits = jax.lax.bitcast_convert_type(arr, udtype)
            flipped = _xor_flips(bits, seed, nflips)
            return jax.lax.bitcast_convert_type(flipped, arr.dtype)
        return _xor_flips(arr, seed, nflips)

    return hook


def _xor_flips(bits: jnp.ndarray, seed: int, nflips: int) -> jnp.ndarray:
    """XOR ``nflips`` seeded (element, bit) positions into an unsigned
    array, traceably (flat scatter on a static index list)."""
    width = bits.dtype.itemsize * 8
    flat = bits.reshape(-1)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, max(flat.shape[0], 1), size=nflips)
    bit = rng.integers(0, width, size=nflips)
    for i, b in zip(idx, bit):
        mask = jnp.asarray(np.array(1, flat.dtype) << np.array(b, flat.dtype))
        flat = flat.at[int(i)].set(flat[int(i)] ^ mask)
    return flat.reshape(bits.shape)


def make_tag_fault_operator(a, mode: str = "indefinite",
                            fail_tag: int = 1) -> Callable:
    """Wrap operator ``a`` so it misbehaves at tags <= ``fail_tag`` only.

    Modes (all exact at tags above ``fail_tag``):

      * ``"indefinite"`` -- negates the product: ``p.Ap`` turns negative
        on the first iteration, the textbook CG breakdown;
      * ``"nan"``        -- multiplies the product by NaN: poisons the
        residual recurrence immediately.

    ``a`` may be a GSECSR (routed through the solvers' memoized tag
    closure) or an ``apply(v, tag)`` callable.  The returned callable has
    the standard tagged-operator signature, so it drives the GENERIC
    solver paths -- a deterministic recoverable low-tag fault for the
    guard + escalation tests: detection must trip at tag <= ``fail_tag``
    and recovery must converge at ``fail_tag + 1`` or the exact tag-3
    rung.
    """
    if mode not in ("indefinite", "nan"):
        raise ValueError(f"mode must be 'indefinite' or 'nan', got {mode!r}")
    if isinstance(a, GSECSR):
        from repro.solvers.cg import _gsecsr_operator
        base = _gsecsr_operator(a)
    else:
        base = a

    def apply(v, tag):
        y = base(v, tag)
        if mode == "indefinite":
            bad = -y
        else:
            bad = y * jnp.asarray(jnp.nan, y.dtype)
        return jnp.where(jnp.asarray(tag) <= fail_tag, bad, y)

    return apply
