"""repro: GSE-SEM precision-aware framework (paper reproduction + LM-scale)."""

__version__ = "1.0.0"
