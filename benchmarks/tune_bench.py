"""Roofline-driven autotune benchmark -> BENCH_roofline.json (PR 7).

Three sections, one JSON (DESIGN.md §15):

  * ``host``    -- the persisted roofline probe (STREAM-triad bandwidth +
    matmul peak, ``perf.roofline.host_roofline``): the denominator every
    fraction below is measured against.
  * ``kernels`` -- per (tag, layout, nrhs) on the skewed benchmark
    matrix: ``perf.autotune`` sweeps the launch axes (BM/BL, SELL
    C/sigma, bucket granularity), and each row reports the ledger-priced
    {flops, bytes, us, achieved_gbps, effective_gbps, roofline_fraction}
    for the DEFAULT plan and the TUNED winner.  Both times come from the
    same sweep (``default_us`` is the sweep's own default-candidate
    measurement), so tuned <= untuned is compared on one clock.  The
    tuned row's ``model_roofline_fraction`` re-prices the tuned time at
    the DEFAULT layout's byte model -- the gate axis: a tuned SELL pack
    that legitimately streams fewer bytes must not read as a roofline
    regression just because its attainable time shrank too.
  * ``formats`` -- the gse_h-vs-fp64 smoke case (satellite 6): jnp-path
    SpMV on the fig6 diagonal matrix under best-of-k MIN timing.  The
    case sits below ``DECODE_BOUND_NNZ`` (launch/decode-bound), so the
    honest axis is wall-clock parity -- ``effective_gbps`` (fp64-
    equivalent bytes / time, same math both sides) within 10% -- not
    physical-GB/s dominance.  The pre-PR-7 median estimator is what made
    this case look like a 10% regression (DESIGN.md §15).

The ``replay`` section drops the in-memory tune-cache image and re-asks
for every plan straight from the persisted file: all hits, ZERO
re-sweeps (the PR-4 ``PACK_STATS``-style counter discipline, gated by
``run.py --tune``).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn

import jax.numpy as jnp  # noqa: E402  (common enables x64 first)


def _kernel_configs(quick: bool):
    if quick:
        return [(1, "ell", 1), (1, "sell", 1), (3, "sell", 1)]
    return [(t, lay, 1) for t in (1, 2, 3) for lay in ("ell", "sell")] + \
           [(1, "ell", 4), (1, "sell", 4)]


def _ledger_pair(g, tag: int, layout: str, nrhs: int, plan):
    """(default-plan ledger, tuned-plan ledger) for one config.

    ELL's slot-honest byte model is blocks-independent (grid padding is
    priced separately by ``pallas_segment_bytes``); SELL's depends on the
    tuned C/sigma/bucket, so the tuned pack is priced exactly.
    """
    from repro.kernels.ops import sell_pack_gsecsr
    from repro.perf.ledger import spmv_ledger

    if layout == "ell":
        led = spmv_ledger(g, tag=tag, layout="ell", nrhs=nrhs)
        return led, led
    led_def = spmv_ledger(g, tag=tag, layout=sell_pack_gsecsr(g), nrhs=nrhs)
    led_tun = spmv_ledger(g, tag=tag, layout=sell_pack_gsecsr(g, plan=plan),
                          nrhs=nrhs)
    return led_def, led_tun


def kernel_sweep(g, roof: dict, quick: bool = False) -> list:
    """Tuned-vs-default roofline rows for every (tag, layout, nrhs)."""
    from repro.perf import autotune, roofline as rl
    from repro.perf.ledger import achieved
    from repro.perf.plan import plan_key, shape_class

    rows = []
    for tag, layout, nrhs in _kernel_configs(quick):
        plan, payload, hit = autotune.get_or_tune(
            g, tag=tag, layout=layout, nrhs=nrhs,
            iters=2 if quick else 3)
        led_def, led_tun = _ledger_pair(g, tag, layout, nrhs, plan)
        untuned = achieved(led_def, payload["default_us"] * 1e-6, roof)
        tuned = achieved(led_tun, payload["us"] * 1e-6, roof)
        # Gate axis: tuned time at the DEFAULT byte model (monotone in
        # wall time, immune to the tuned pack shrinking the stream).
        tuned["model_roofline_fraction"] = rl.fraction(
            led_def.flops, led_def.bytes, payload["us"] * 1e-6, roof)
        row = {
            "key": plan_key(shape_class(g), tag, layout, nrhs),
            "tag": tag, "layout": layout, "nrhs": nrhs,
            "plan": plan.to_dict(), "cache_hit": hit,
            "decode_bound": payload["decode_bound"],
            "untuned": untuned, "tuned": tuned,
            "speedup": payload["default_us"] / max(payload["us"], 1e-9),
        }
        rows.append(row)
        emit(f"tune/{row['key']}", payload["us"],
             f"default={payload['default_us']:.1f}us "
             f"speedup={row['speedup']:.2f} "
             f"roofline={tuned['roofline_fraction']:.3f} "
             f"(untuned {untuned['roofline_fraction']:.3f}) hit={hit}")
    return rows


def format_case(roof: dict, n: int = 3000, iters: int = 30) -> dict:
    """gse_h vs fp64 on the fig6 diagonal smoke case, min-timed.

    Returns both sides' ledger-priced rates plus the parity ratio the
    ``run.py --tune`` gate bounds; ``decode_bound`` records which side of
    the measured crossover (``autotune.DECODE_BOUND_NNZ``) the case sits
    on, i.e. which gate axis is honest here.
    """
    from repro.perf import autotune
    from repro.perf.ledger import achieved, spmv_ledger
    from repro.sparse import generators as G
    from repro.sparse.csr import pack_csr
    from repro.sparse.spmv import spmv, spmv_gse

    a = G.mass_diagonal(n)
    g = pack_csr(a, k=8)
    x = jnp.ones((a.shape[1],), jnp.float64)

    us_fp64 = time_fn(lambda: spmv(a, x), iters=iters)
    us_gse = time_fn(lambda: spmv_gse(g, x, tag=1), iters=iters)
    led_fp64 = spmv_ledger(a, jnp_path=True)
    led_gse = spmv_ledger(g, tag=1, jnp_path=True)
    out = {
        "matrix": f"mass_diag_{n}",
        "nnz": int(a.nnz),
        "decode_bound": autotune.decode_bound(a),
        "fp64": achieved(led_fp64, us_fp64 * 1e-6, roof),
        "gse_h": achieved(led_gse, us_gse * 1e-6, roof),
        # Wall-clock parity axis (>= 1.0 means gse_h is no slower; the
        # effective-GB/s ratio is the same number since both sides price
        # the identical fp64-equivalent math).
        "parity": us_fp64 / max(us_gse, 1e-9),
    }
    emit(f"tune/formats/{out['matrix']}", us_gse,
         f"fp64={us_fp64:.1f}us parity={out['parity']:.3f} "
         f"gse_eff={out['gse_h']['effective_gbps']:.2f}GBps "
         f"fp64={out['fp64']['achieved_gbps']:.2f}GBps "
         f"decode_bound={out['decode_bound']}")
    return out


def replay(g, quick: bool = False) -> dict:
    """Drop the in-memory cache image and re-resolve every plan from the
    persisted file: must be all hits, zero re-sweeps."""
    from repro.perf import autotune, tunecache

    tunecache.clear_memory()
    before = dict(tunecache.TUNE_STATS)
    hits = 0
    for tag, layout, nrhs in _kernel_configs(quick):
        _, _, hit = autotune.get_or_tune(g, tag=tag, layout=layout,
                                         nrhs=nrhs)
        hits += bool(hit)
    after = dict(tunecache.TUNE_STATS)
    out = {
        "configs": len(_kernel_configs(quick)),
        "hits": hits,
        "sweeps": after["sweeps"] - before["sweeps"],
        "stores": after["stores"] - before["stores"],
        "tune_stats": after,
    }
    emit("tune/replay", 0.0,
         f"hits={hits}/{out['configs']} resweeps={out['sweeps']}")
    return out


def run(quick: bool = False) -> dict:
    """Full tuned-roofline sweep; returns the BENCH_roofline.json payload."""
    from repro.perf import roofline as rl, tunecache
    from repro.sparse import generators as G
    from repro.sparse.csr import pack_csr

    roof = rl.host_roofline(quick=quick)
    emit("tune/host_roofline", 0.0,
         f"stream={roof['stream_gbps']:.1f}GBps "
         f"peak={roof['peak_gflops']:.1f}GFLOPs probed={roof['probed']}")

    a = G.skewed_spd(512 if quick else 1024)
    g = pack_csr(a, k=8)
    kernels = kernel_sweep(g, roof, quick=quick)
    # iters stays 30 even in quick mode: the case is ~150 us/call and the
    # min estimator needs the sample depth right after the kernel sweep
    # polluted the caches (0.89 parity at 10 iters, 0.99 at 30).
    formats = format_case(roof, n=3000, iters=30)
    rep = replay(g, quick=quick)
    return {
        "host": roof,
        "matrix": {"name": f"skewed_{a.shape[0]}", "nnz": int(a.nnz)},
        "kernels": kernels,
        "formats": formats,
        "replay": rep,
        "tune_cache": str(tunecache.cache_path()),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(quick=True), indent=2, sort_keys=True))
