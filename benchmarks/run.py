"""Benchmark harness: one module per paper table/figure (deliverable d).

Prints ``name,us_per_call,derived`` CSV rows.

  fig1   -- Fig. 1   value/exponent/mantissa entropy, top-k coverage
  fig45  -- Figs 4/5 shared-exponent count k sweep (speed + error)
  fig6   -- Fig. 6   SpMV format comparison (GSE-SEM vs FP16/BF16/FP64)
  tab34  -- Tables III/IV  CG/GMRES convergence per format
  fig89  -- Figs 8/9 solver wall time + GSE-SEM* projection (Eq. 7)
  lm     -- beyond-paper: GSE-SEM LM weight serving ladder
  roofline -- dry-run roofline table (deliverable g)
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig45,fig6,tab34,"
                         "fig89,lm,roofline")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    from benchmarks import (fig1_entropy, fig45_k_sweep, fig6_spmv_formats,
                            fig89_solver_time, lm_gse_serving, roofline,
                            tab34_solver_convergence)

    suites = {
        "fig1": fig1_entropy.run,
        "fig45": fig45_k_sweep.run,
        "fig6": fig6_spmv_formats.run,
        "tab34": tab34_solver_convergence.run,
        "fig89": fig89_solver_time.run,
        "lm": lm_gse_serving.run,
        "roofline": roofline.run,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if want and name not in want:
            continue
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
