"""Benchmark harness: one module per paper table/figure (deliverable d).

Prints ``name,us_per_call,derived`` CSV rows.

  fig1   -- Fig. 1   value/exponent/mantissa entropy, top-k coverage
  fig45  -- Figs 4/5 shared-exponent count k sweep (speed + error)
  fig6   -- Fig. 6   SpMV format comparison (GSE-SEM vs FP16/BF16/FP64)
  tab34  -- Tables III/IV  CG/GMRES convergence per format
  fig89  -- Figs 8/9 solver wall time + GSE-SEM* projection (Eq. 7)
  lm     -- beyond-paper: GSE-SEM LM weight serving ladder
  roofline -- dry-run roofline table (deliverable g)

``--quick`` runs a trimmed fig6 SpMV sweep and writes ``BENCH_spmv.json``
(format/tag x time x modeled GB/s from the ``bytes_touched`` accounting)
at the repo root -- the perf-trajectory artifact CI regresses against.

``--precond {none,jacobi,spai0}`` adds stepped preconditioned rows to
fig89 (GSE-packed preconditioner riding the operator's tag schedule;
preconditioner bytes charged at the per-iteration tag actually run).

``--nrhs N`` (N > 1) adds batched multi-RHS stepped-CG rows to fig89
(matrix bytes charged once per iteration, vector bytes per active
column); with ``--quick`` it instead runs a trimmed batched solve and
writes ``BENCH_batch.json`` -- per-request iterations/residual plus the
bytes/iteration ratio vs nrhs=1 the acceptance bar bounds (< 2x at
nrhs=4 on the stream-dominated smoke matrix).

``--shards N`` (N > 1) adds row-sharded distributed stepped-CG rows to
fig89 (per-shard matrix streams + tag-aware halo wire bytes, DESIGN.md
section 13); with ``--quick`` it instead runs the distributed smoke and
writes ``BENCH_dist.json``, gating exact-wire parity with ``solve_cg``,
the per-shard byte-sum identity, and the tag-1 < 50% tag-3 halo wire
ladder.  Forces ``N`` host CPU devices when XLA_FLAGS is unset.

``--tune`` runs the autotune + roofline sweep (benchmarks/tune_bench.py,
DESIGN.md section 15) and writes ``BENCH_roofline.json``: per-kernel
{flops, bytes, achieved_gbps, roofline_fraction} for default and tuned
launch plans, the gse_h-vs-fp64 parity case, and a persisted-cache
replay pass.  Gates on roofline FRACTION (tuned >= untuned), wall-clock
parity below the decode crossover, and zero re-sweeps on replay -- never
on absolute microseconds.  Composes with ``--quick``.

``--robust`` runs the fault-injection / recovery / guard-overhead sweep
(benchmarks/robust_bench.py, DESIGN.md section 14) and writes
``BENCH_robust.json``, gating 100% detection of injected pack/cache/wire
corruption and 100% recovery of the low-tag operator faults.  Forces two
host CPU devices (for the wire-checksum harness) when XLA_FLAGS is
unset.  Composes with ``--quick`` for the trimmed CI smoke.

``--serve`` runs the chaos traffic-replay harness for the async solve
service (benchmarks/serve_bench.py, DESIGN.md section 17) and writes
``BENCH_serve.json``: p50/p95/p99 end-to-end latency, shed counts, and a
per-family chaos ledger (pack + pack-cache corruption, wire faults,
operand faults, slow-shard stalls, queue bursts).  Gates 100% chaos
detection, zero UNFLAGGED non-finite solutions, typed shedding under
overload with a bounded shed rate, and a loose absolute p99 bound (the
injected stall skew dominates, so the gate is not wall-clock noise).
Forces two host CPU devices (for the sharded wire-fault case) when
XLA_FLAGS is unset.  Composes with ``--quick`` for the trimmed CI smoke.

``--obs`` runs the observability sweep (benchmarks/obs_bench.py,
DESIGN.md section 16) and writes ``BENCH_obs.json`` plus a span capture
``TRACE_obs.jsonl``, gating recorder-on/off bit identity across every
solver family, flight-vs-monitor telemetry consistency, the <= 1.10
flight+span overhead ratio, and trace schema validity.  The serve-replay
section reports p50/p95/p99 flush latency and bytes/request straight
from the metrics registry.  Forces two host CPU devices (for the sharded
identity case) when XLA_FLAGS is unset.  Composes with ``--quick``.

``--adaptive`` runs the per-group precision sweep
(benchmarks/adaptive_bench.py, DESIGN.md section 18) and writes
``BENCH_adaptive.json``: on the ill-conditioned and skewed generators,
uniform pinned tag-{1,2,3} CG baselines vs the data-driven TagMap
schedule from ``solve_adaptive``.  Gates the adaptive run to an
equal-or-better TRUE (tag-3) residual with STRICTLY fewer streamed
bytes than the best uniform schedule that meets tolerance.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import traceback

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(_REPO_ROOT) not in sys.path:  # allow `python benchmarks/run.py`
    sys.path.insert(0, str(_REPO_ROOT))


def _write_payload(payload: dict, path: pathlib.Path) -> None:
    """Stamp the provenance header (DESIGN.md §16) and write the artifact.

    Every BENCH_*.json carries WHAT produced it -- git sha, jax/jaxlib
    versions, device kind, host roofline, UTC timestamp -- so a regression
    diff can tell a code change from an environment change.  Written
    BEFORE any gate raises so a failing run still uploads diagnostics.
    """
    from benchmarks import common

    payload["provenance"] = common.provenance()
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {path}", file=sys.stderr)


def run_quick(out_path: pathlib.Path | None = None) -> dict:
    """CI smoke mode: trimmed SpMV format sweep -> BENCH_spmv.json.

    The ``skewed_layouts`` entry compares uniform-ELL vs SELL-C-σ padding
    on the skewed benchmark matrix and is gated (DESIGN.md §12): the SELL
    layout must waste < 50% of uniform ELL's padded-slot fraction, stream
    < 50% of its modeled tag-1 bytes, and keep tag-1 effective bytes
    within 10% of the 6 B/nnz the format promises.  The JSON is written
    BEFORE the gate raises so a failing run still uploads diagnostics.
    """
    from benchmarks import fig6_spmv_formats

    results = fig6_spmv_formats.run(quick=True)
    payload = {
        "bench": "spmv_formats_quick",
        "schema": "matrix -> format -> {us, err, gflops, bytes_per_nnz, "
                  "bytes_touched, model_gbps}; skewed_layouts -> "
                  "{ell, sell} -> {slots, padding_ratio, bytes_touched_tagT,"
                  " bytes_per_nnz_tag1}",
        "results": results,
    }
    _write_payload(payload, out_path or (_REPO_ROOT / "BENCH_spmv.json"))

    lay = results["skewed_layouts"]["layouts"]
    sell, ell = lay["sell"], lay["ell"]
    if not sell["padding_ratio"] < 0.5 * ell["padding_ratio"]:
        raise SystemExit(
            f"skewed smoke: SELL padding_ratio {sell['padding_ratio']:.4f} "
            f"not < 0.5x uniform-ELL's {ell['padding_ratio']:.4f}"
        )
    if not sell["bytes_touched_tag1"] < 0.5 * ell["bytes_touched_tag1"]:
        raise SystemExit(
            f"skewed smoke: SELL tag-1 bytes {sell['bytes_touched_tag1']} "
            f"not < 50% of uniform-ELL's {ell['bytes_touched_tag1']}"
        )
    if abs(sell["bytes_per_nnz_tag1"] - 6.0) / 6.0 > 0.10:
        raise SystemExit(
            f"skewed smoke: SELL tag-1 effective {sell['bytes_per_nnz_tag1']:.3f} "
            "B/nnz strayed > 10% from the 6 B/nnz format promise"
        )
    return payload


def run_quick_batch(nrhs: int, out_path: pathlib.Path | None = None) -> dict:
    """CI batched smoke: one multi-RHS stepped CG -> BENCH_batch.json.

    Runs ``solve_cg_batched`` over ``nrhs`` right-hand sides sharing one
    packed random-SPD operand (nnz/row high enough that the matrix
    segments dominate the stream) and records the byte-model headline:
    bytes/iteration at ``nrhs`` vs the unchanged nrhs=1 figure.
    """
    from benchmarks import fig89_solver_time
    from repro.core.precision import MonitorParams
    from repro.sparse import generators as G
    from repro.sparse.csr import pack_csr

    a = G.random_spd(600, seed=5)
    g = pack_csr(a, k=8)
    params = MonitorParams(t=40, l=60, m=30, rsd_limit=0.5, reldec_limit=0.45)
    case = fig89_solver_time.batched_case(a, g, nrhs, params=params,
                                          maxiter=1500, seed=5)
    payload = {
        "bench": "batched_multirhs_quick",
        "schema": "batched stepped CG over random_spd_600: per-column "
                  "iters/relres/switches + bytes/iteration vs nrhs=1",
        "matrix": "random_spd_600",
        "results": case,
    }
    _write_payload(payload, out_path or (_REPO_ROOT / "BENCH_batch.json"))
    if not all(case["converged"]):
        raise SystemExit("batched smoke: not all columns converged")
    if nrhs >= 2 and case["per_iter_ratio"] >= 2.0:
        raise SystemExit(
            f"batched smoke: bytes/iteration ratio {case['per_iter_ratio']:.2f} "
            f"at nrhs={nrhs} not < 2x the nrhs=1 figure"
        )
    return payload


def run_quick_dist(shards: int, out_path: pathlib.Path | None = None) -> dict:
    """CI distributed smoke: row-sharded stepped CG -> BENCH_dist.json.

    Runs ``fig89.dist_case`` (Poisson 24^2 over ``shards`` forced host
    devices) and gates the distributed contracts (DESIGN.md §13):

      * convergence (exact AND gse wire) with the exact-wire trajectory
        within 1e-10 of single-device ``solve_cg``;
      * the byte-model identity -- per-shard matrix streams + shared
        terms sum EXACTLY to the single-device ``iteration_stream_bytes``;
      * the halo wire ladder -- tag-1 wire bytes < 50% of tag-3's.

    The JSON is written BEFORE the gates raise so a failing run still
    uploads diagnostics.
    """
    from benchmarks import fig89_solver_time
    from repro.core.precision import MonitorParams
    from repro.sparse import generators as G
    from repro.sparse.csr import pack_csr

    a = G.poisson2d(24)
    g = pack_csr(a, k=8)
    params = MonitorParams(t=40, l=60, m=30, rsd_limit=0.5, reldec_limit=0.45)
    case = fig89_solver_time.dist_case(a, g, shards, wire="gse",
                                       params=params, tol=1e-8,
                                       maxiter=2000, seed=7)
    payload = {
        "bench": "distributed_sharded_quick",
        "schema": "row-sharded stepped CG over poisson2d_24: exact-wire "
                  "parity vs solve_cg, per-shard byte model + halo wire "
                  "ladder (DESIGN.md section 13)",
        "matrix": "poisson2d_24",
        "results": case,
    }
    _write_payload(payload, out_path or (_REPO_ROOT / "BENCH_dist.json"))
    if not case["converged"]:
        raise SystemExit("dist smoke: gse-wire sharded run did not converge")
    if case["exact_iters"] != case["ref_iters"]:
        raise SystemExit(
            f"dist smoke: exact-wire iters {case['exact_iters']} != "
            f"single-device {case['ref_iters']}"
        )
    if case["exact_x_maxdiff"] > 1e-10:
        raise SystemExit(
            f"dist smoke: exact-wire trajectory strayed "
            f"{case['exact_x_maxdiff']:.2e} > 1e-10 from single-device"
        )
    if not case["byte_sum_identity"]:
        raise SystemExit(
            "dist smoke: per-shard bytes + shared terms != single-device "
            "iteration_stream_bytes"
        )
    w = case["halo_wire_bytes"]
    if not w[1] < 0.5 * w[3]:
        raise SystemExit(
            f"dist smoke: tag-1 halo wire bytes {w[1]} not < 50% of "
            f"tag-3's {w[3]}"
        )
    return payload


def run_robust(quick: bool, out_path: pathlib.Path | None = None) -> dict:
    """Robustness sweep: fault detection + recovery -> BENCH_robust.json.

    Gates (DESIGN.md §14): every seeded pack/cache/wire corruption must be
    DETECTED (rate == 1.0) and every low-tag operator fault must RECOVER
    through tag escalation to a converged finite solution (rate == 1.0).
    The clean-path guard-overhead ratio rides along in the JSON (the
    acceptance bar is <= 1.10 on quiet hardware) but is not hard-gated --
    shared CI runners make wall-clock ratios too noisy to fail a build on.
    The JSON is written BEFORE the gates raise so a failing run still
    uploads diagnostics.
    """
    from benchmarks import robust_bench

    results = robust_bench.run(quick=quick)
    payload = {
        "bench": "robustness_fault_injection",
        "schema": "detection -> {cases, rate, wire_skipped}; recovery -> "
                  "{cases, rate}; overhead -> {guards_on_s, guards_off_s, "
                  "ratio} (DESIGN.md section 14)",
        "results": results,
    }
    _write_payload(payload, out_path or (_REPO_ROOT / "BENCH_robust.json"))

    det = results["detection"]
    if det["wire_skipped"]:
        raise SystemExit(
            "robust sweep: wire-checksum cases skipped (need >= 2 devices; "
            "run.py forces them when XLA_FLAGS is unset)"
        )
    if det["rate"] != 1.0:
        missed = [k for k, v in det["cases"].items() if not v]
        raise SystemExit(
            f"robust sweep: detection rate {det['rate']:.3f} != 1.0; "
            f"missed {missed}"
        )
    rec = results["recovery"]
    if rec["rate"] != 1.0:
        missed = [k for k, v in rec["cases"].items() if not v["recovered"]]
        raise SystemExit(
            f"robust sweep: recovery rate {rec['rate']:.3f} != 1.0; "
            f"failed {missed}"
        )
    if results["overhead"]["ratio"] > 1.10:
        print(
            f"WARNING: clean-path guard overhead ratio "
            f"{results['overhead']['ratio']:.3f} > 1.10 "
            "(not gated: wall-clock noise)", file=sys.stderr,
        )
    return payload


def run_tune(quick: bool, out_path: pathlib.Path | None = None) -> dict:
    """Autotune + roofline sweep -> BENCH_roofline.json (DESIGN.md §15).

    Gates on ROOFLINE FRACTION and counter discipline, not absolute
    microseconds (heterogeneous CI hosts move the roof and the
    measurement together):

      * every tuned plan is no slower than the default on the sweep's own
        measurements, and its roofline fraction at the shared byte model
        is no lower than the untuned one;
      * the gse_h-vs-fp64 smoke case holds wall-clock parity (>= 0.90)
        under min timing -- the case sits below the measured
        decode-overhead crossover (``autotune.DECODE_BOUND_NNZ``), where
        byte savings cannot show up in wall time; above the crossover the
        gate tightens to effective-GB/s dominance;
      * the replay pass re-resolves every plan from the PERSISTED cache:
        all hits, zero re-sweeps.

    The JSON is written BEFORE the gates raise so a failing run still
    uploads diagnostics.
    """
    from benchmarks import tune_bench

    results = tune_bench.run(quick=quick)
    payload = {
        "bench": "autotune_roofline",
        "schema": "host -> {stream_gbps, peak_gflops}; kernels -> per "
                  "(tag, layout, nrhs) {untuned, tuned} x {flops, bytes, "
                  "us, achieved_gbps, effective_gbps, roofline_fraction}; "
                  "formats -> gse_h vs fp64 parity; replay -> cache-hit "
                  "counters (DESIGN.md section 15)",
        "results": results,
    }
    _write_payload(payload, out_path or (_REPO_ROOT / "BENCH_roofline.json"))

    for row in results["kernels"]:
        if row["speedup"] < 1.0 - 1e-9:
            raise SystemExit(
                f"tune sweep: tuned plan slower than default on {row['key']}"
                f" (speedup {row['speedup']:.3f})"
            )
        if (row["tuned"]["model_roofline_fraction"]
                < row["untuned"]["roofline_fraction"] - 1e-9):
            raise SystemExit(
                f"tune sweep: tuned roofline fraction "
                f"{row['tuned']['model_roofline_fraction']:.4f} below "
                f"untuned {row['untuned']['roofline_fraction']:.4f} on "
                f"{row['key']}"
            )
    fmt = results["formats"]
    if fmt["decode_bound"]:
        if fmt["parity"] < 0.90:
            raise SystemExit(
                f"tune sweep: gse_h wall-clock parity {fmt['parity']:.3f} "
                "< 0.90 vs fp64 on the decode-bound smoke case"
            )
    elif fmt["gse_h"]["effective_gbps"] < fmt["fp64"]["achieved_gbps"]:
        raise SystemExit(
            f"tune sweep: gse_h effective "
            f"{fmt['gse_h']['effective_gbps']:.2f} GB/s below fp64's "
            f"{fmt['fp64']['achieved_gbps']:.2f} above the crossover"
        )
    rep = results["replay"]
    if rep["hits"] != rep["configs"] or rep["sweeps"] != 0:
        raise SystemExit(
            f"tune sweep: replay hit {rep['hits']}/{rep['configs']} plans "
            f"with {rep['sweeps']} re-sweeps (want all hits, zero sweeps)"
        )
    return payload


def run_serve(quick: bool, out_path: pathlib.Path | None = None) -> dict:
    """Chaos traffic replay -> BENCH_serve.json (DESIGN.md §17).

    Gates:

      * every chaos family is DETECTED/handled (rate == 1.0): pack and
        pack-cache corruption repacked, wire + operand faults flagged
        (breaker opens, then heals), deadline expiries returned as
        flagged checkpoints, queue bursts shed typed responses;
      * ZERO unflagged non-finite solutions -- a NaN that reaches a
        caller must carry health != "ok";
      * overload sheds typed responses (both families occurred) and the
        shed rate stays below 0.9 -- the service degrades, it does not
        collapse;
      * p99 end-to-end latency (by the service's own skewed clock) under
        a loose 60 s absolute bound: the deterministic stall injection
        dominates it, so the gate catches pathological re-queue loops,
        not CI jitter.

    The JSON is written BEFORE the gates raise so a failing run still
    uploads diagnostics.
    """
    from benchmarks import serve_bench

    results = serve_bench.run(quick=quick)
    payload = {
        "bench": "serve_chaos_replay",
        "schema": "traffic -> {submitted, completed, sheds, shed_rate, "
                  "warm, max_batch}; latency_s -> {p50, p95, p99}; chaos "
                  "-> {cases, rate, wire_skipped}; unflagged_nonfinite "
                  "(DESIGN.md section 17)",
        "results": results,
    }
    _write_payload(payload, out_path or (_REPO_ROOT / "BENCH_serve.json"))

    chaos = results["chaos"]
    if chaos["wire_skipped"]:
        raise SystemExit(
            "serve replay: wire-fault case skipped (need >= 2 devices; "
            "run.py forces them when XLA_FLAGS is unset)"
        )
    if chaos["rate"] != 1.0:
        missed = [k for k, v in chaos["cases"].items() if not v]
        raise SystemExit(
            f"serve replay: chaos detection rate {chaos['rate']:.3f} != "
            f"1.0; missed {missed}"
        )
    if results["unflagged_nonfinite"] != 0:
        raise SystemExit(
            f"serve replay: {results['unflagged_nonfinite']} non-finite "
            "solution(s) returned without a health flag"
        )
    traffic = results["traffic"]
    if traffic["sheds"]["queue_full"] < 1 \
            or traffic["sheds"]["breaker_open"] < 1:
        raise SystemExit(
            f"serve replay: expected both shed families under the chaos "
            f"trace, got {traffic['sheds']}"
        )
    if traffic["shed_rate"] >= 0.9:
        raise SystemExit(
            f"serve replay: shed rate {traffic['shed_rate']:.2f} >= 0.9 "
            "(the service collapsed instead of degrading)"
        )
    if results["latency_s"]["p99"] > 60.0:
        raise SystemExit(
            f"serve replay: p99 latency {results['latency_s']['p99']:.1f}"
            " s over the 60 s bound (requests re-queued pathologically?)"
        )
    return payload


def run_obs(quick: bool, out_path: pathlib.Path | None = None,
            trace_path: pathlib.Path | None = None) -> dict:
    """Observability sweep -> BENCH_obs.json + TRACE_obs.jsonl (§16).

    Runs ``benchmarks/obs_bench.py`` under a span capture and gates:

      * every recorder-on solve is BIT-IDENTICAL to recorder-off (and its
        telemetry consistent with the solver's own monitor/guard report)
        across CG fused/guarded, PCG, GMRES, batched, and sharded;
      * the clean-path overhead ratio with flight + spans active is
        <= 1.10 (the observability twin of the guard-overhead bar);
      * the captured trace JSONL round-trips through the schema
        validator (``repro.obs.trace.validate_jsonl``).

    The JSON and trace are written BEFORE the gates raise so a failing
    run still uploads diagnostics.
    """
    from benchmarks import obs_bench
    from repro.obs import trace as OT

    tpath = trace_path or (_REPO_ROOT / "TRACE_obs.jsonl")
    with OT.capture(str(tpath)):
        results = obs_bench.run(quick=quick)
    print(f"wrote {tpath}", file=sys.stderr)
    payload = {
        "bench": "observability",
        "schema": "bit_identity -> case -> {identical, consistent, rows, "
                  "switch_iters}; overhead -> {obs_on_s, obs_off_s, ratio}"
                  "; serve -> {flush_latency_s, request_bytes, stats}; "
                  "metrics -> registry exposition (DESIGN.md section 16)",
        "results": results,
    }
    _write_payload(payload, out_path or (_REPO_ROOT / "BENCH_obs.json"))

    n_events = OT.validate_jsonl(str(tpath))
    if n_events < 1:
        raise SystemExit("obs sweep: trace capture recorded no spans")
    for name, case in results["bit_identity"].items():
        if "skipped" in case:
            raise SystemExit(
                f"obs sweep: {name} identity case skipped ({case['skipped']}"
                "; run.py forces 2 host devices when XLA_FLAGS is unset)"
            )
        if not case["identical"]:
            raise SystemExit(
                f"obs sweep: recorder-on solve NOT bit-identical on {name}"
            )
        if not case["consistent"]:
            raise SystemExit(
                f"obs sweep: flight telemetry inconsistent with the "
                f"solver's own report on {name}"
            )
    if results["overhead"]["ratio"] > 1.10:
        raise SystemExit(
            f"obs sweep: flight+span overhead ratio "
            f"{results['overhead']['ratio']:.3f} > 1.10"
        )
    lat = results["serve"]["flush_latency_s"]
    if not lat["count"] or lat["p99"] is None:
        raise SystemExit("obs sweep: serve replay recorded no flush latency")
    return payload


def run_adaptive(quick: bool, out_path: pathlib.Path | None = None) -> dict:
    """Adaptive per-group precision sweep -> BENCH_adaptive.json (§18).

    Runs ``benchmarks/adaptive_bench.py``: on the ill-conditioned and
    skewed generators, the data-driven per-group tag map must reach an
    equal-or-better TRUE (tag-3) residual with STRICTLY fewer total
    streamed bytes than the best uniform-tag schedule that meets the
    same tolerance.  Uniform baselines pin the monitor (``max_tag=t`` +
    ``tags=t``) and are charged ``(iters+1) * bytes_touched(t)`` plus
    one tag-3 true-residual pass; the adaptive run bills its own
    ``spmv_bytes`` counter (blended segments + billed true checks).
    The JSON is written BEFORE the gates raise so a failing run still
    uploads diagnostics.
    """
    from benchmarks import adaptive_bench

    results = adaptive_bench.run(quick=quick)
    payload = {
        "bench": "adaptive_tagmap",
        "schema": "case -> {uniform: [{tag, iters, true_relres, bytes, "
                  "meets_tol}], adaptive: {profile, iters, true_relres, "
                  "bytes, tag_counts, promotions, chunks}, "
                  "best_uniform_bytes, savings_frac} (DESIGN.md "
                  "section 18)",
        "results": results,
    }
    _write_payload(payload, out_path or (_REPO_ROOT / "BENCH_adaptive.json"))

    for name, case in results.items():
        ad = case["adaptive"]
        if not ad["converged"]:
            raise SystemExit(
                f"adaptive sweep: {name} adaptive solve did not converge "
                f"(true relres {ad['true_relres']:.3e})"
            )
        if ad["true_relres"] > case["tol"]:
            raise SystemExit(
                f"adaptive sweep: {name} adaptive TRUE residual "
                f"{ad['true_relres']:.3e} misses tol {case['tol']:g}"
            )
        best = case["best_uniform_bytes"]
        if best is None:
            raise SystemExit(
                f"adaptive sweep: {name} has no qualifying uniform "
                "baseline (every pinned tag missed tolerance)"
            )
        if not ad["bytes"] < best:
            raise SystemExit(
                f"adaptive sweep: {name} adaptive bytes {ad['bytes']} not "
                f"strictly < best uniform {best}"
            )
        print(
            f"adaptive sweep: {name} saves "
            f"{100 * case['savings_frac']:.1f}% bytes vs best uniform "
            f"(map {ad['tag_counts']})", file=sys.stderr,
        )
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig45,fig6,tab34,"
                         "fig89,lm,roofline")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: trimmed SpMV sweep, emit "
                         "BENCH_spmv.json and exit")
    ap.add_argument("--precond", default="none",
                    choices=["none", "jacobi", "spai0"],
                    help="add stepped preconditioned solver rows to fig89 "
                         "(GSE-packed preconditioner riding the tag "
                         "schedule; includes the ill-conditioned CG case)")
    ap.add_argument("--nrhs", type=int, default=1,
                    help="batch width for the multi-RHS rows: > 1 adds "
                         "batched stepped-CG rows to fig89, or (with "
                         "--quick) runs the batched smoke and writes "
                         "BENCH_batch.json")
    ap.add_argument("--layout", default="nnz", choices=["nnz", "sell"],
                    help="fig89 byte model: 'sell' charges the GSE rows "
                         "the SELL-C-sigma layout's actual padded slots "
                         "instead of nnz only (DESIGN.md section 12)")
    ap.add_argument("--shards", type=int, default=1,
                    help="shard count for the distributed rows: > 1 adds "
                         "row-sharded stepped-CG rows to fig89, or (with "
                         "--quick) runs the distributed smoke and writes "
                         "BENCH_dist.json (forces that many host CPU "
                         "devices if XLA_FLAGS is unset)")
    ap.add_argument("--tune", action="store_true",
                    help="autotune + roofline sweep -> BENCH_roofline.json"
                         ", gating roofline fraction (tuned >= untuned), "
                         "gse_h/fp64 parity, and zero-re-sweep cache "
                         "replay (DESIGN.md section 15); composes with "
                         "--quick for the CI smoke")
    ap.add_argument("--robust", action="store_true",
                    help="fault-injection / recovery / guard-overhead "
                         "sweep -> BENCH_robust.json, gating 100% "
                         "detection and recovery (DESIGN.md section 14; "
                         "forces 2 host CPU devices if XLA_FLAGS is unset)")
    ap.add_argument("--serve", action="store_true",
                    help="chaos traffic replay against the async solve "
                         "service -> BENCH_serve.json, gating 100% chaos "
                         "detection, zero unflagged non-finite solutions, "
                         "typed shedding, and a loose absolute p99 bound "
                         "(DESIGN.md section 17; forces 2 host CPU "
                         "devices if XLA_FLAGS is unset)")
    ap.add_argument("--obs", action="store_true",
                    help="observability sweep -> BENCH_obs.json + "
                         "TRACE_obs.jsonl, gating recorder-on/off bit "
                         "identity, the <= 1.10 flight+span overhead "
                         "ratio, and trace schema validity (DESIGN.md "
                         "section 16; forces 2 host CPU devices if "
                         "XLA_FLAGS is unset)")
    ap.add_argument("--adaptive", action="store_true",
                    help="adaptive per-group precision sweep -> "
                         "BENCH_adaptive.json, gating the data-driven "
                         "tag map to equal-or-better TRUE residual with "
                         "strictly fewer streamed bytes than the best "
                         "uniform-tag schedule on the ill-conditioned "
                         "and skewed generators (DESIGN.md section 18)")
    args = ap.parse_args()
    if args.quick and args.only:
        ap.error("--quick and --only are mutually exclusive")
    if args.nrhs < 1:
        ap.error("--nrhs must be >= 1")
    if args.shards < 1:
        ap.error("--shards must be >= 1")
    if args.quick and args.shards > 1 and args.nrhs > 1:
        ap.error("--quick runs ONE smoke: pass --shards or --nrhs, not "
                 "both (the CI jobs run them separately)")
    if args.robust and (args.shards > 1 or args.nrhs > 1 or args.only):
        ap.error("--robust is its own sweep: drop --shards/--nrhs/--only")
    if args.tune and (args.robust or args.shards > 1 or args.nrhs > 1
                      or args.only):
        ap.error("--tune is its own sweep: drop "
                 "--robust/--shards/--nrhs/--only")
    if args.obs and (args.robust or args.tune or args.shards > 1
                     or args.nrhs > 1 or args.only):
        ap.error("--obs is its own sweep: drop "
                 "--robust/--tune/--shards/--nrhs/--only")
    if args.serve and (args.robust or args.tune or args.obs
                       or args.shards > 1 or args.nrhs > 1 or args.only):
        ap.error("--serve is its own sweep: drop "
                 "--robust/--tune/--obs/--shards/--nrhs/--only")
    if args.adaptive and (args.robust or args.tune or args.obs
                          or args.serve or args.shards > 1
                          or args.nrhs > 1 or args.only):
        ap.error("--adaptive is its own sweep: drop "
                 "--robust/--tune/--obs/--serve/--shards/--nrhs/--only")
    force_devices = args.shards if args.shards > 1 else (
        2 if args.robust or args.obs or args.serve else 0)
    if force_devices and "xla_force_host_platform_device_count" not in (
            os.environ.get("XLA_FLAGS", "")):
        # Must land before jax initializes (all jax imports are lazy,
        # below): the distributed rows / wire-checksum harness need the
        # forced host devices.
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={force_devices}"
        ).strip()

    print("name,us_per_call,derived")
    if args.adaptive:
        run_adaptive(quick=args.quick)
        return
    if args.serve:
        run_serve(quick=args.quick)
        return
    if args.obs:
        run_obs(quick=args.quick)
        return
    if args.robust:
        run_robust(quick=args.quick)
        return
    if args.tune:
        run_tune(quick=args.quick)
        return
    if args.quick:
        if args.shards > 1:  # distributed smoke only; the SpMV sweep and
            run_quick_dist(args.shards)  # batched smoke are other jobs
        elif args.nrhs > 1:
            run_quick_batch(args.nrhs)
        else:
            run_quick()
        return
    want = set(args.only.split(",")) if args.only else None

    from benchmarks import (fig1_entropy, fig45_k_sweep, fig6_spmv_formats,
                            fig89_solver_time, lm_gse_serving, roofline,
                            tab34_solver_convergence)

    from functools import partial

    suites = {
        "fig1": fig1_entropy.run,
        "fig45": fig45_k_sweep.run,
        "fig6": fig6_spmv_formats.run,
        "tab34": tab34_solver_convergence.run,
        "fig89": partial(fig89_solver_time.run, precond=args.precond,
                         nrhs=args.nrhs, layout=args.layout,
                         shards=args.shards),
        "lm": lm_gse_serving.run,
        "roofline": roofline.run,
    }
    failed = []
    for name, fn in suites.items():
        if want and name not in want:
            continue
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
