"""Benchmark harness: one module per paper table/figure (deliverable d).

Prints ``name,us_per_call,derived`` CSV rows.

  fig1   -- Fig. 1   value/exponent/mantissa entropy, top-k coverage
  fig45  -- Figs 4/5 shared-exponent count k sweep (speed + error)
  fig6   -- Fig. 6   SpMV format comparison (GSE-SEM vs FP16/BF16/FP64)
  tab34  -- Tables III/IV  CG/GMRES convergence per format
  fig89  -- Figs 8/9 solver wall time + GSE-SEM* projection (Eq. 7)
  lm     -- beyond-paper: GSE-SEM LM weight serving ladder
  roofline -- dry-run roofline table (deliverable g)

``--quick`` runs a trimmed fig6 SpMV sweep and writes ``BENCH_spmv.json``
(format/tag x time x modeled GB/s from the ``bytes_touched`` accounting)
at the repo root -- the perf-trajectory artifact CI regresses against.

``--precond {none,jacobi,spai0}`` adds stepped preconditioned rows to
fig89 (GSE-packed preconditioner riding the operator's tag schedule;
preconditioner bytes charged at the per-iteration tag actually run).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import traceback

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(_REPO_ROOT) not in sys.path:  # allow `python benchmarks/run.py`
    sys.path.insert(0, str(_REPO_ROOT))


def run_quick(out_path: pathlib.Path | None = None) -> dict:
    """CI smoke mode: trimmed SpMV format sweep -> BENCH_spmv.json."""
    from benchmarks import fig6_spmv_formats

    results = fig6_spmv_formats.run(quick=True)
    payload = {
        "bench": "spmv_formats_quick",
        "schema": "matrix -> format -> {us, err, gflops, bytes_per_nnz, "
                  "bytes_touched, model_gbps}",
        "results": results,
    }
    path = out_path or (_REPO_ROOT / "BENCH_spmv.json")
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {path}", file=sys.stderr)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig45,fig6,tab34,"
                         "fig89,lm,roofline")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: trimmed SpMV sweep, emit "
                         "BENCH_spmv.json and exit")
    ap.add_argument("--precond", default="none",
                    choices=["none", "jacobi", "spai0"],
                    help="add stepped preconditioned solver rows to fig89 "
                         "(GSE-packed preconditioner riding the tag "
                         "schedule; includes the ill-conditioned CG case)")
    args = ap.parse_args()
    if args.quick and args.only:
        ap.error("--quick and --only are mutually exclusive")

    print("name,us_per_call,derived")
    if args.quick:
        run_quick()
        return
    want = set(args.only.split(",")) if args.only else None

    from benchmarks import (fig1_entropy, fig45_k_sweep, fig6_spmv_formats,
                            fig89_solver_time, lm_gse_serving, roofline,
                            tab34_solver_convergence)

    from functools import partial

    suites = {
        "fig1": fig1_entropy.run,
        "fig45": fig45_k_sweep.run,
        "fig6": fig6_spmv_formats.run,
        "tab34": tab34_solver_convergence.run,
        "fig89": partial(fig89_solver_time.run, precond=args.precond),
        "lm": lm_gse_serving.run,
        "roofline": roofline.run,
    }
    failed = []
    for name, fn in suites.items():
        if want and name not in want:
            continue
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
