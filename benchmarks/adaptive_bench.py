"""Adaptive per-group precision sweep (DESIGN.md §18) -> BENCH_adaptive.json.

Two generators where a uniform tag schedule is provably wasteful, each
solved three ways:

  * ``ill_conditioned_spd(16, decades=8.0)`` -- a handful of row groups
    carry the extreme diagonal decades; tag-1's decode floor blocks the
    TRUE residual at ~1.1x the 2e-3 tolerance while tag-2 streams 30%
    more bytes than necessary for every row.  The adaptive driver
    (default explore profile) runs cheap, measures which groups' decode
    floor dominates, and promotes exactly those.
  * ``diag_rescale(skewed_spd(n=1024), 6.0)`` -- power-law rows + dense
    hubs with 6 decades of diagonal skew.  Here the upfront Neumann
    probe profile plans the map before iterating: the hub groups land at
    tag 2, the power-law tail stays at tag 1.

For every case the uniform baselines pin the monitor (``max_tag=t`` +
``tags=t``: no stepping, a pure tag-t schedule), charge
``(iters+1) * bytes_touched(t)`` plus one tag-3 pass for the final true
check, and a baseline only qualifies if its TRUE tag-3 residual meets
the tolerance.  The adaptive run bills its own ``spmv_bytes`` counter
(every segment at the blended map rate + every billed true check at
tag 3).  The gate in run.py: adaptive converged at equal-or-better true
residual with STRICTLY fewer bytes than the best qualifying uniform
schedule, on both generators.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import common  # noqa: F401  (enables x64 before jax use)


def _spike_rhs(m: int, k: int = 4, seed: int = 7) -> np.ndarray:
    """k unit spikes at rng-chosen rows: localized, exercises the skew."""
    b = np.zeros(m)
    b[np.random.default_rng(seed).choice(m, k, replace=False)] = 1.0
    return b


def _uniform_case(g, b, tag: int, tol: float, maxiter: int,
                  params) -> dict:
    """Pinned uniform tag-``tag`` CG: the schedule the map competes with."""
    import jax.numpy as jnp

    from repro.solvers.cg import solve_cg
    from repro.sparse.spmv import spmv_gse

    r = solve_cg(g, b, tol=tol, maxiter=maxiter,
                 params=dataclasses.replace(params, max_tag=tag), tags=tag)
    bn = float(jnp.linalg.norm(b))
    true = float(jnp.linalg.norm(b - spmv_gse(g, r.x, tag=3))) / bn
    by = (int(r.iters) + 1) * g.bytes_touched(tag) + g.bytes_touched(3)
    return {
        "tag": tag,
        "iters": int(r.iters),
        "true_relres": true,
        "bytes": int(by),
        "meets_tol": bool(true <= tol),
    }


def _adaptive_case(g, b, tol: float, maxiter: int, profile: str) -> dict:
    from repro.solvers.adaptive import solve_adaptive

    res = solve_adaptive(g, b, tol=tol, maxiter=maxiter, profile=profile)
    counts = {int(t): int(c) for t, c in res.tagmap.tag_counts().items()
              if c}
    return {
        "profile": profile,
        "iters": int(res.iters),
        "true_relres": float(res.true_relres),
        "bytes": int(res.spmv_bytes),
        "converged": bool(res.converged),
        "tag_counts": counts,
        "max_tag": int(res.tagmap.max_tag),
        "promotions": len(res.promotions),
        "chunks": int(res.chunks),
    }


def _case(name: str, g, b, tol: float, maxiter: int, profile: str,
          params) -> dict:
    uniform = [_uniform_case(g, b, t, tol, maxiter, params)
               for t in (1, 2, 3)]
    adaptive = _adaptive_case(g, b, tol, maxiter, profile)
    qualifying = [u["bytes"] for u in uniform if u["meets_tol"]]
    best_uniform = min(qualifying) if qualifying else None
    savings = (1.0 - adaptive["bytes"] / best_uniform
               if best_uniform else None)
    out = {
        "matrix": name,
        "n": int(g.shape[0]),
        "tol": tol,
        "maxiter": maxiter,
        "uniform": uniform,
        "adaptive": adaptive,
        "best_uniform_bytes": best_uniform,
        "savings_frac": savings,
    }
    pct = f"{100 * savings:.1f}%" if savings is not None else "n/a"
    print(f"adaptive_{name},0.0,bytes={adaptive['bytes']} "
          f"best_uniform={best_uniform} savings={pct}")
    return out


def run(quick: bool = True) -> dict:
    """Both gated generators; ``quick`` is accepted for harness symmetry
    (the cases ARE the smoke -- the gate needs both)."""
    import jax.numpy as jnp

    from repro.core.precision import MonitorParams
    from repro.sparse import generators as G
    from repro.sparse.csr import pack_csr

    params = MonitorParams.for_cg()
    results = {}

    ill = G.ill_conditioned_spd(16, decades=8.0, seed=0)
    gi = pack_csr(ill, k=8)
    bi = jnp.asarray(_spike_rhs(int(gi.shape[0])))
    results["illcond"] = _case("ill_conditioned_spd_256", gi, bi,
                               tol=2e-3, maxiter=4000, profile="explore",
                               params=params)

    sk = G.diag_rescale(G.skewed_spd(n=1024), 6.0, 11)
    gs = pack_csr(sk, k=8)
    bs = jnp.asarray(_spike_rhs(int(gs.shape[0])))
    results["skewed"] = _case("skewed_spd_1024_rescaled", gs, bs,
                              tol=1e-3, maxiter=1500, profile="neumann",
                              params=params)
    return results
