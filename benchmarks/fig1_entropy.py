"""Paper Fig. 1: value/exponent/mantissa entropy + top-k exponent coverage.

Validates the paper's motivating claim on the synthetic SuiteSparse
stand-in suite: exponent entropy << value entropy; top-8 coverage ~90%.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.gse import exponent_stats
from repro.sparse import generators as G


def run() -> dict:
    suite = G.spmv_suite(small=True)
    rows = {}
    agg = {k: [] for k in
           ("entropy_value", "entropy_exponent", "entropy_mantissa",
            "top1", "top2", "top4", "top8", "top16", "top32", "top64")}
    for name, a in suite.items():
        st = exponent_stats(np.asarray(a.val))
        rows[name] = st
        for k in agg:
            agg[k].append(st[k])
        emit(
            f"fig1/{name}", 0.0,
            f"H_val={st['entropy_value']:.2f} H_exp={st['entropy_exponent']:.2f}"
            f" H_man={st['entropy_mantissa']:.2f} top8={st['top8']:.3f}"
        )
    means = {k: float(np.mean(v)) for k, v in agg.items()}
    emit(
        "fig1/MEAN", 0.0,
        f"H_exp_mean={means['entropy_exponent']:.2f} "
        f"top1={means['top1']:.3f} top8={means['top8']:.3f} "
        f"top64={means['top64']:.3f} "
        f"(paper: 64.7%/90.9%/99.8% for top1/8/64)"
    )
    return {"rows": rows, "means": means}


if __name__ == "__main__":
    run()
