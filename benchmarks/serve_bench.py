"""Chaos traffic-replay bench for the async solve service (DESIGN.md §17).

Replays a seeded request trace against :class:`repro.serve.AsyncSolveService`
while injecting every fault family the service claims to survive:

  * **pack corruption** -- seeded bit-flips in a registered handle's packed
    GSE segments (``robustness.faults.corrupt_gsecsr``); the pre-dispatch
    CRC verify must DETECT and repack from the retained CSR, and the solve
    must still converge.
  * **pack-cache corruption** -- bit-flips swapped into the operator's
    memoized ``kernels.ops._cached_pack`` entry behind the stored checksum
    (``corrupt_pack_cache``); the next cache hit must detect and repack
    (``PACK_STATS['corrupt']``).
  * **wire faults** -- a NaN hook on the sharded halo exchange
    (``distributed.wire.set_wire_fault``); the poisoned SpMV must come back
    FLAGGED by the guards (never an unflagged non-finite x), and the handle
    must solve cleanly again once the hook is lifted.  Needs >= 2 devices
    (``run.py --serve`` forces them when XLA_FLAGS is unset); skipped and
    reported otherwise.
  * **operand faults** -- a handle whose operator NaNs at every tag
    (``make_tag_fault_operator``): each request is flagged, the per-handle
    circuit breaker OPENS after ``fail_threshold`` consecutive trips and
    sheds further traffic with ``retry_after_s``; once the injected
    operator is lifted the half-open probe heals the handle.
  * **slow-shard stalls** -- a chunk hook charges wall-clock skew to the
    service clock for one handle's groups, so its requests blow their
    deadlines mid-solve and must come back as FLAGGED checkpoints
    (``health="deadline"``), never silently dropped.
  * **queue-full bursts** -- a submission burst past ``queue_limit`` must
    shed typed ``Shed("queue_full")`` responses, not block or drop.

The replay reports p50/p95/p99 end-to-end latency (by the service's own
clock, stall skew included), the shed rate, per-family detection flags,
and the count of UNFLAGGED non-finite solutions -- the headline gate is
detection == 1.0 with zero unflagged non-finites.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit

import jax  # noqa: E402  (common enables x64 first)
import jax.numpy as jnp

_STALL_HANDLE = "stall"


def _params():
    from repro.core.precision import MonitorParams
    return MonitorParams(t=30, l=30, m=15, rsd_limit=0.5, reldec_limit=0.45)


class _SkewClock:
    """Monotonic clock plus injectable skew: the stall hook charges fake
    seconds to the service (deadlines, breaker backoff, latency) without
    the bench actually sleeping."""

    def __init__(self):
        self.skew = 0.0

    def __call__(self) -> float:
        return time.monotonic() + self.skew


def _rhs(a, seed: int):
    from repro.sparse.spmv import spmv

    rng = np.random.default_rng(seed)
    return spmv(a, jnp.asarray(rng.normal(size=a.shape[1])))


def _finite(x) -> bool:
    return x is not None and bool(jnp.isfinite(jnp.vdot(x, x)))


def _nan_wire_hook(target: str):
    def hook(name, arr):
        if name != target:
            return arr
        flat = arr.reshape(-1)
        if jnp.issubdtype(arr.dtype, jnp.floating):
            return flat.at[0].set(jnp.nan).reshape(arr.shape)
        return flat.at[0].set(flat[0] ^ jnp.asarray(1, flat.dtype)
                              ).reshape(arr.shape)

    return hook


def run(quick: bool = False, seed: int = 0) -> dict:
    from repro.robustness.faults import (corrupt_gsecsr, corrupt_pack_cache,
                                         make_tag_fault_operator)
    from repro.serve import AsyncSolveService, BreakerParams, Shed
    from repro.sparse import generators as G
    from repro.sparse.csr import pack_csr

    n_clean = 16 if quick else 24
    repeats = 2 if quick else 4
    burst = 14

    clk = _SkewClock()
    stall_s = 0.6

    def stall_hook(svc, key, group):
        if key[0] == _STALL_HANDLE:
            clk.skew += stall_s

    svc = AsyncSolveService(
        slots=4, params=_params(), maxiter=6000, chunk_iters=24,
        queue_limit=8,
        breaker=BreakerParams(fail_threshold=2, backoff_s=0.2,
                              backoff_mult=2.0, jitter=0.1),
        warm_capacity=8, clock=clk, seed=seed, chunk_hook=stall_hook,
    )
    a_clean = G.poisson2d(n_clean)
    a_flaky = G.poisson2d(12)
    svc.register("clean", a_clean, k=8)
    svc.register(_STALL_HANDLE, a_clean, k=8)
    g_flaky = pack_csr(a_flaky, k=8)
    svc.register("flaky", a_flaky, k=8,
                 operator=make_tag_fault_operator(g_flaky, mode="nan",
                                                  fail_tag=3))

    submit_t: dict[int, float] = {}
    latencies: list[float] = []
    reports = {}
    sheds = {"queue_full": 0, "breaker_open": 0}
    submitted = 0

    def submit(handle, b, **kw):
        nonlocal submitted
        submitted += 1
        resp = svc.submit(handle, b, **kw)
        if isinstance(resp, Shed):
            sheds[resp.reason] += 1
            return None
        submit_t[resp.id] = clk()
        return resp.id

    def drain():
        done = svc.run_until_idle()
        for rid, rep in done.items():
            if rid in submit_t:
                latencies.append(clk() - submit_t[rid])
        reports.update(done)
        return done

    cases: dict[str, bool] = {}

    # -- phase 1: clean warm-up traffic (continuous batching + warm LRU) --
    clean_bs = [_rhs(a_clean, s) for s in range(3)]
    for _ in range(repeats):
        for b in clean_bs:
            submit("clean", b, tol=1e-8)
    drain()
    cases["clean_traffic_converges"] = all(
        r.converged for r in reports.values())

    # -- phase 2: queue-full burst --------------------------------------
    burst_ids = [submit("clean", _rhs(a_clean, 100 + s), tol=1e-8)
                 for s in range(burst)]
    drain()
    cases["queue_full_shed"] = sheds["queue_full"] > 0
    cases["burst_accepted_all_converge"] = all(
        reports[rid].converged for rid in burst_ids if rid is not None)

    # -- phase 3: operand-fault storm -> breaker opens ------------------
    flaky_reports = []
    for s in range(4):
        rid = submit("flaky", _rhs(a_flaky, 200 + s), tol=1e-8)
        drain()
        if rid is not None:
            flaky_reports.append(reports[rid])
    cases["operand_fault_flagged"] = bool(flaky_reports) and all(
        (not r.converged) and r.health != "ok" for r in flaky_reports)
    cases["breaker_opened"] = sheds["breaker_open"] > 0

    # -- phase 4: lift the fault; half-open probe heals the handle ------
    del svc._operators["flaky"]  # the injected operator, not the pack
    clk.skew += 30.0             # past any jittered backoff
    rid = submit("flaky", _rhs(a_flaky, 300), tol=1e-8)
    drain()
    cases["breaker_recovered"] = (rid is not None
                                  and reports[rid].converged
                                  and reports[rid].health == "ok")

    # -- phase 5: pack corruption detected + repacked -------------------
    det0 = int(svc.pack_faults["detected"])
    op = svc._ops["clean"]
    op.gse = corrupt_gsecsr(op.gse, "table", seed=seed + 7)
    rid = submit("clean", _rhs(a_clean, 400), tol=1e-8)
    drain()
    cases["pack_corruption_detected"] = (
        int(svc.pack_faults["detected"]) > det0
        and rid is not None and reports[rid].converged)

    # -- phase 6: pack-cache corruption detected on the next hit --------
    # The memoized layout packs (kernels.ops._cached_pack) carry their own
    # checksum: populate the handle's SELL entry (as a SELL-layout dispatch
    # would), corrupt it behind the stored checksum, and the next hit must
    # detect + repack -- while the handle keeps serving.
    from repro.kernels.ops import PACK_STATS, sell_pack_gsecsr

    gse = svc._ops["clean"].gse
    sell_pack_gsecsr(gse)  # populate the entry under test
    c0 = int(PACK_STATS["corrupt"])
    corrupted = corrupt_pack_cache(gse, seed=seed + 11)
    sell_pack_gsecsr(gse)  # next dispatch: verify -> detect -> repack
    rid = submit("clean", _rhs(a_clean, 500), tol=1e-8)
    drain()
    cases["pack_cache_corruption_detected"] = (
        corrupted and int(PACK_STATS["corrupt"]) > c0
        and rid is not None and reports[rid].converged)

    # -- phase 7: wire fault on a sharded handle (needs >= 2 devices) ---
    # The NaN hook is applied at TRACE time, so it must be live when the
    # faulted handle's solve first compiles: a freshly registered handle
    # (its own operator closure -> fresh trace) bakes the fault in, while
    # the pre-chaos handle's compiled entries stay clean.
    wire_skipped = jax.device_count() < 2
    if not wire_skipped:
        from repro.distributed.wire import set_wire_fault

        svc.register("shard", a_clean, k=8, sharded=True, shards=2,
                     wire="exact")
        rid = submit("shard", _rhs(a_clean, 600), tol=1e-8)
        drain()
        ok_before = rid is not None and reports[rid].converged
        set_wire_fault(_nan_wire_hook("raw"))
        try:
            svc.register("shard_faulted", a_clean, k=8, sharded=True,
                         shards=2, wire="exact")
            rid_bad = submit("shard_faulted", _rhs(a_clean, 601), tol=1e-8)
            drain()
        finally:
            set_wire_fault(None)
        bad = reports.get(rid_bad)
        flagged = (bad is not None and not bad.converged
                   and bad.health != "ok")
        rid_heal = submit("shard", _rhs(a_clean, 602), tol=1e-8)
        drain()
        healed = rid_heal is not None and reports[rid_heal].converged
        cases["wire_fault_flagged"] = ok_before and flagged and healed

    # -- phase 8: slow-shard stall -> deadline checkpoint ---------------
    stall_ids = [submit(_STALL_HANDLE, _rhs(a_clean, 700 + s),
                        tol=1e-13, deadline_s=1.0)
                 for s in range(2)]
    drain()
    stall_reports = [reports[rid] for rid in stall_ids if rid is not None]
    cases["deadline_flagged_checkpoint"] = bool(stall_reports) and all(
        r.deadline_exceeded and r.health == "deadline"
        and _finite(svc.solution(r.id)) for r in stall_reports)

    # -- roll-up ---------------------------------------------------------
    unflagged_nonfinite = sum(
        1 for r in reports.values()
        if r.health == "ok" and not _finite(svc.solution(r.id)))
    lat = np.asarray(sorted(latencies)) if latencies else np.asarray([0.0])
    completed = len(reports)
    shed_total = sum(sheds.values())
    detection_rate = (sum(cases.values()) / len(cases)) if cases else 0.0

    for name, ok in sorted(cases.items()):
        emit(f"serve.case.{name}", 0.0, int(ok))
    emit("serve.latency.p99_ms", float(np.percentile(lat, 99)) * 1e3,
         completed)

    return {
        "traffic": {
            "submitted": submitted,
            "completed": completed,
            "sheds": dict(sheds),
            "shed_rate": shed_total / max(submitted, 1),
            "warm": {k: int(svc.warm[k]) for k in ("hit", "miss", "store")},
            "max_batch": max((r.batch_size for r in reports.values()),
                             default=0),
        },
        "latency_s": {
            "p50": float(np.percentile(lat, 50)),
            "p95": float(np.percentile(lat, 95)),
            "p99": float(np.percentile(lat, 99)),
            "count": len(latencies),
        },
        "chaos": {
            "cases": cases,
            "rate": detection_rate,
            "wire_skipped": wire_skipped,
        },
        "unflagged_nonfinite": unflagged_nonfinite,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(quick=True), indent=2, default=str))
