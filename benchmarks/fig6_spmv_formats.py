"""Paper Fig. 6: SpMV across storage formats -- GFLOPS proxy + maxAbsErr.

FP64 / FP32 / FP16 / BF16 vs GSE-SEM tags 1..3.  The paper's headline:
GSE-SEM head (16-bit) has FAR smaller error than FP16/BF16 at the same
width, at comparable bandwidth savings.

Bandwidth is reported from the containers' ``bytes_touched`` accounting
(measured-model, not a constant): per-nnz matrix-stream bytes are 6/8/12
for GSE-SEM tags 1/2/3 vs 12 for FP64 CSR, and modeled GB/s divides the
per-call byte count by the measured wall time.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.sparse.spmv import spmv, spmv_gse


def run(quick: bool = False) -> dict:
    """Sweep formats over the SpMV suite.  ``quick`` trims matrices and
    timing iterations for the CI smoke mode (``run.py --quick``)."""
    out = {}
    suite = G.spmv_suite(small=True)
    if quick:
        suite = dict(list(suite.items())[:2])
    iters = 3 if quick else 10
    for name, a in suite.items():
        x = jnp.ones((a.shape[1],), jnp.float64)
        ref = np.asarray(spmv(a, x))
        g = pack_csr(a, k=8)
        flops = 2.0 * a.nnz
        rows = {}
        cases = {
            "fp64": (lambda: spmv(a, x), jnp.float64, None),
            "fp32": (lambda: spmv(a, x, store_dtype=jnp.float32),
                     jnp.float32, None),
            "fp16": (lambda: spmv(a, x, store_dtype=jnp.float16),
                     jnp.float16, None),
            "bf16": (lambda: spmv(a, x, store_dtype=jnp.bfloat16),
                     jnp.bfloat16, None),
            "gse_h": (lambda: spmv_gse(g, x, tag=1), None, 1),
            "gse_ht1": (lambda: spmv_gse(g, x, tag=2), None, 2),
            "gse_full": (lambda: spmv_gse(g, x, tag=3), None, 3),
        }
        for label, (fn, store_dtype, tag) in cases.items():
            y = np.asarray(fn())
            err = float(np.abs(y - ref).max())
            us = time_fn(fn, iters=iters)
            if tag is None:
                bpn = int(a.bytes_per_nnz(store_dtype))
                btot = int(a.bytes_touched(store_dtype))
            else:
                bpn = int(g.bytes_per_nnz(tag))
                btot = int(g.bytes_touched(tag))
            gbps = btot / us / 1e3  # bytes per us -> GB/s
            rows[label] = dict(err=err, us=us, gflops=flops / us / 1e3,
                               bytes_per_nnz=bpn, bytes_touched=btot,
                               model_gbps=gbps)
            emit(f"fig6/{name}/{label}", us,
                 f"maxAbsErr={err:.3e} gflops={flops/us/1e3:.2f} "
                 f"B/nnz={bpn} modelGBps={gbps:.2f}")
        out[name] = rows
        better = (rows["gse_h"]["err"] <= rows["fp16"]["err"] + 1e-300 and
                  rows["gse_h"]["err"] <= rows["bf16"]["err"] + 1e-300)
        emit(f"fig6/{name}/gse_head_beats_16bit", 0.0, str(better))
    return out


if __name__ == "__main__":
    run()
