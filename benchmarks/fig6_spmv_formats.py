"""Paper Fig. 6: SpMV across storage formats -- GFLOPS proxy + maxAbsErr.

FP64 / FP32 / FP16 / BF16 vs GSE-SEM tags 1..3.  The paper's headline:
GSE-SEM head (16-bit) has FAR smaller error than FP16/BF16 at the same
width, at comparable bandwidth savings.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.sparse.spmv import spmv, spmv_gse


def run() -> dict:
    out = {}
    suite = G.spmv_suite(small=True)
    for name, a in suite.items():
        x = jnp.ones((a.shape[1],), jnp.float64)
        ref = np.asarray(spmv(a, x))
        g = pack_csr(a, k=8)
        flops = 2.0 * a.nnz
        rows = {}
        for label, fn in {
            "fp64": lambda: spmv(a, x),
            "fp32": lambda: spmv(a, x, store_dtype=jnp.float32),
            "fp16": lambda: spmv(a, x, store_dtype=jnp.float16),
            "bf16": lambda: spmv(a, x, store_dtype=jnp.bfloat16),
            "gse_h": lambda: spmv_gse(g, x, tag=1),
            "gse_ht1": lambda: spmv_gse(g, x, tag=2),
            "gse_full": lambda: spmv_gse(g, x, tag=3),
        }.items():
            y = np.asarray(fn())
            err = float(np.abs(y - ref).max())
            us = time_fn(fn, iters=10)
            rows[label] = dict(err=err, us=us, gflops=flops / us / 1e3)
            emit(f"fig6/{name}/{label}", us,
                 f"maxAbsErr={err:.3e} gflops={flops/us/1e3:.2f}")
        out[name] = rows
        better = (rows["gse_h"]["err"] <= rows["fp16"]["err"] + 1e-300 and
                  rows["gse_h"]["err"] <= rows["bf16"]["err"] + 1e-300)
        emit(f"fig6/{name}/gse_head_beats_16bit", 0.0, str(better))
    return out


if __name__ == "__main__":
    run()
