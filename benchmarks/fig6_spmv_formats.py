"""Paper Fig. 6: SpMV across storage formats -- GFLOPS proxy + maxAbsErr.

FP64 / FP32 / FP16 / BF16 vs GSE-SEM tags 1..3.  The paper's headline:
GSE-SEM head (16-bit) has FAR smaller error than FP16/BF16 at the same
width, at comparable bandwidth savings.

Bandwidth is reported from the containers' ``bytes_touched`` accounting
(measured-model, not a constant): per-nnz matrix-stream bytes are 6/8/12
for GSE-SEM tags 1/2/3 vs 12 for FP64 CSR, and modeled GB/s divides the
per-call byte count by the measured wall time.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.sparse import generators as G
from repro.sparse.csr import ell_layout, pack_csr
from repro.sparse.spmv import spmv, spmv_gse


def skewed_layout_case(n: int = 1024, seed: int = 0) -> dict:
    """Uniform-ELL vs SELL-C-σ padding on the skewed benchmark matrix
    (DESIGN.md §12) -- the ``run.py --quick`` CI job gates on this.

    Reports, per layout and per tag, the ACTUAL padded slots the packed
    kernels stream (``bytes_touched``), the effective bytes/nnz, and
    ``padding_ratio`` (wasted-slot fraction) -- the numbers the nnz-only
    format figures above cannot see.  Also cross-checks that the SELL
    reference SpMV matches the CSR reference bitwise (the layouts differ
    in traffic, never in arithmetic).
    """
    from repro.kernels.ops import sell_pack_gsecsr

    a = G.skewed_spd(n, seed=seed)
    g = pack_csr(a, k=8)
    sell = sell_pack_gsecsr(g)
    layouts = {"ell": ell_layout(g), "sell": sell}

    x = jnp.ones((a.shape[1],), jnp.float64)
    want = np.asarray(spmv_gse(g, x, tag=1))
    got = np.asarray(spmv_gse(sell, x, tag=1))
    if not np.array_equal(want, got):
        raise AssertionError("SELL reference SpMV diverged from CSR")

    out = {"matrix": f"skewed_{n}", "nnz": int(a.nnz), "layouts": {}}
    for name, lay in layouts.items():
        row = dict(
            slots=int(lay.slots),
            padding_ratio=float(lay.padding_ratio),
            **{f"bytes_touched_tag{t}": int(lay.bytes_touched(t))
               for t in (1, 2, 3)},
            bytes_per_nnz_tag1=lay.bytes_touched(1) / a.nnz,
        )
        if name == "sell":
            row["widths"] = list(sell.widths)
            row["us_spmv_tag1"] = time_fn(
                lambda: spmv_gse(sell, x, tag=1), iters=3
            )
        out["layouts"][name] = row
        emit(f"fig6/skewed_{n}/{name}", row.get("us_spmv_tag1", 0.0),
             f"padding_ratio={row['padding_ratio']:.4f} "
             f"tag1B/nnz={row['bytes_per_nnz_tag1']:.2f} "
             f"slots={row['slots']}")
    return out


def run(quick: bool = False) -> dict:
    """Sweep formats over the SpMV suite.  ``quick`` trims matrices and
    timing iterations for the CI smoke mode (``run.py --quick``)."""
    from repro.perf import roofline as rl

    out = {}
    suite = G.spmv_suite(small=True)
    if quick:
        suite = dict(list(suite.items())[:2])
    iters = 3 if quick else 10
    # Host roofline (persisted probe: re-runs re-probe nothing) -- every
    # format row reports attainable-time / measured-time (DESIGN.md §15).
    roof = rl.host_roofline(quick=quick)
    for name, a in suite.items():
        x = jnp.ones((a.shape[1],), jnp.float64)
        ref = np.asarray(spmv(a, x))
        g = pack_csr(a, k=8)
        flops = 2.0 * a.nnz
        rows = {}
        cases = {
            "fp64": (lambda: spmv(a, x), jnp.float64, None),
            "fp32": (lambda: spmv(a, x, store_dtype=jnp.float32),
                     jnp.float32, None),
            "fp16": (lambda: spmv(a, x, store_dtype=jnp.float16),
                     jnp.float16, None),
            "bf16": (lambda: spmv(a, x, store_dtype=jnp.bfloat16),
                     jnp.bfloat16, None),
            "gse_h": (lambda: spmv_gse(g, x, tag=1), None, 1),
            "gse_ht1": (lambda: spmv_gse(g, x, tag=2), None, 2),
            "gse_full": (lambda: spmv_gse(g, x, tag=3), None, 3),
        }
        for label, (fn, store_dtype, tag) in cases.items():
            y = np.asarray(fn())
            err = float(np.abs(y - ref).max())
            us = time_fn(fn, iters=iters)
            if tag is None:
                bpn = int(a.bytes_per_nnz(store_dtype))
                btot = int(a.bytes_touched(store_dtype))
            else:
                bpn = int(g.bytes_per_nnz(tag))
                btot = int(g.bytes_touched(tag))
            gbps = btot / us / 1e3  # bytes per us -> GB/s
            # jnp reference path: charge the segment-sum decode's row_ids
            # stream (nnz * 4 B) on top of the container byte model.
            frac = rl.fraction(flops, btot + a.nnz * 4, us * 1e-6, roof)
            rows[label] = dict(err=err, us=us, gflops=flops / us / 1e3,
                               bytes_per_nnz=bpn, bytes_touched=btot,
                               model_gbps=gbps, roofline_fraction=frac)
            emit(f"fig6/{name}/{label}", us,
                 f"maxAbsErr={err:.3e} gflops={flops/us/1e3:.2f} "
                 f"B/nnz={bpn} modelGBps={gbps:.2f} roofline={frac:.3f}")
        out[name] = rows
        better = (rows["gse_h"]["err"] <= rows["fp16"]["err"] + 1e-300 and
                  rows["gse_h"]["err"] <= rows["bf16"]["err"] + 1e-300)
        emit(f"fig6/{name}/gse_head_beats_16bit", 0.0, str(better))
    # Padding-honest layout comparison on the skewed worst case
    # (DESIGN.md §12): what the nnz-only rows above cannot show.  The
    # size stays 1024 in quick mode -- the dense-row blowup the gate
    # bounds needs the dense rows >> one lane tile, and the case is a
    # host-side pack + one jnp SpMV, not a kernel sweep.
    out["skewed_layouts"] = skewed_layout_case(1024)
    return out


if __name__ == "__main__":
    run()
