"""Paper Tables III/IV: solver iterations + relative residuals per format.

CG (SPD suite) and GMRES (asymmetric suite) with FP64 / FP16 / BF16 /
stepped GSE-SEM.  Expected phenomenology (paper): FP16 overflows or
stalls on wide-range matrices, BF16 converges slowly or stalls, stepped
GSE-SEM tracks FP64 (sometimes in fewer iterations).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.precision import MonitorParams
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.solvers import (
    make_fixed_operator,
    make_gse_operator,
    solve_cg,
    solve_gmres,
)

_PARAMS = MonitorParams(t=40, l=60, m=30, rsd_limit=0.5, reldec_limit=0.45)


def _b(a, seed):
    rng = np.random.default_rng(seed)
    from repro.sparse.spmv import spmv

    return jnp.asarray(np.asarray(spmv(a, jnp.asarray(
        rng.normal(size=a.shape[1])))))


def _fmt(res):
    it = int(res.iters)
    rr = float(res.relres)
    rr_s = "/" if not np.isfinite(rr) else f"{rr:.1e}"
    return it, rr, rr_s


def run(maxiter_cg=1500, maxiter_gm=3000) -> dict:
    out = {"cg": {}, "gmres": {}}

    for i, (name, a) in enumerate(G.cg_suite(small=True).items()):
        if a is None:
            continue
        b = _b(a, i)
        g = pack_csr(a, k=8)
        rows = {}
        for label, op in {
            "fp64": make_fixed_operator(a),
            "fp16": make_fixed_operator(a, store_dtype=jnp.float16),
            "bf16": make_fixed_operator(a, store_dtype=jnp.bfloat16),
            "gse": make_gse_operator(g),
        }.items():
            res = solve_cg(op, b, tol=1e-6, maxiter=maxiter_cg,
                           params=_PARAMS)
            it, rr, rr_s = _fmt(res)
            rows[label] = (it, rr)
            emit(f"tab4_cg/{name}/{label}", 0.0,
                 f"iters={it} relres={rr_s} tag={int(res.tag)}")
        out["cg"][name] = rows

    for i, (name, a) in enumerate(G.gmres_suite(small=True).items()):
        b = _b(a, 100 + i)
        g = pack_csr(a, k=8)
        rows = {}
        for label, op in {
            "fp64": make_fixed_operator(a),
            "fp16": make_fixed_operator(a, store_dtype=jnp.float16),
            "bf16": make_fixed_operator(a, store_dtype=jnp.bfloat16),
            "gse": make_gse_operator(g),
        }.items():
            res = solve_gmres(op, b, tol=1e-6, restart=30,
                              maxiter=maxiter_gm, params=_PARAMS)
            it, rr, rr_s = _fmt(res)
            rows[label] = (it, rr)
            emit(f"tab3_gmres/{name}/{label}", 0.0,
                 f"iters={it} relres={rr_s} tag={int(res.tag)}")
        out["gmres"][name] = rows
    return out


if __name__ == "__main__":
    run()
