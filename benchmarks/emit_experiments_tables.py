"""Regenerate the EXPERIMENTS.md roofline table + perf log from
dryrun_results/*.json (keeps the report reproducible)."""
import glob
import json
import os

RES = os.path.join(os.path.dirname(__file__), "..", "dryrun_results")


def _lever(r) -> str:
    """One sentence: what would move the dominant term down (per cell)."""
    b = r["bottleneck"]
    arch = r["arch"]
    shape = r["shape"]
    moe = "moe" in arch or "grok" in arch
    if b == "collective":
        if moe and "train" in shape or moe and "prefill" in shape:
            return ("shard-local MoE dispatch kills the global-sort "
                    "gathers (proven 20x in §Perf A)")
        if "decode" in shape:
            return ("serving rules: drop FSDP on weights (TP-only) -- "
                    "proven in §Perf B1")
        return ("overlap FSDP gathers with compute (scan scheduler) + "
                "GSE-SEM head wire format on the pod axis")
    if b == "memory":
        if "decode" in shape or "long" in shape:
            return ("GSE-SEM 8-bit KV cache + tag-1 weight segments "
                    "(proven 2.4x in §Perf B)")
        return ("flash-attention Pallas kernel (kernels/flash_attn.py) "
                "keeps score tiles in VMEM; bf16 stored params halve "
                "weight reads")
    return "MXU-aligned tiling; larger per-chip batch"


def roofline_markdown() -> str:
    rows = []
    for p in sorted(glob.glob(os.path.join(RES, "*__baseline.json"))):
        r = json.load(open(p))
        if r.get("skipped") or "error" in r:
            continue
        rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = ["| arch | shape | mesh | comp (s) | mem (s) | coll (s) | bound "
           "| frac | useful | lever for the dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.4g} | {r['t_memory_s']:.4g} "
            f"| {r['t_collective_s']:.4g} | {r['bottleneck']} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {r['model_over_hlo_flops']:.2f} "
            f"| {_lever(r)} |"
        )
    skips = []
    for p in sorted(glob.glob(os.path.join(RES, "*__baseline.json"))):
        r = json.load(open(p))
        if r.get("skipped"):
            skips.append(f"{r['arch']}/{r['shape']}")
    out.append("")
    out.append(f"Skipped cells (sub-quadratic-only shape): "
               f"{len(skips)} -- {', '.join(sorted(set(skips)))}")
    return "\n".join(out)


def perf_markdown() -> str:
    cells = {
        "A qwen3_moe_235b_a22b/train_4k":
            ["qwen3_moe_235b_a22b__train_4k__sp__{}.json",
             ["baseline", "opt1_grouped", "opt2_grouped_gather",
              "opt3_grouped_bf16g"]],
        "B qwen15_32b/decode_32k":
            ["qwen15_32b__decode_32k__sp__{}.json",
             ["baseline", "opt1_serve_rules", "opt2_gse_t1", "opt3_kv_u8"]],
        "C granite_34b/train_4k":
            ["granite_34b__train_4k__sp__{}.json",
             ["baseline", "opt1_bf16gather", "opt2_remat_dots",
              "opt3_dots_chunked"]],
    }
    out = []
    for name, (pat, tags) in cells.items():
        out.append(f"**Cell {name}**\n")
        out.append("| variant | comp (s) | mem (s) | coll (s) | bound |")
        out.append("|---|---|---|---|---|")
        for t in tags:
            p = os.path.join(RES, pat.format(t))
            if not os.path.exists(p):
                continue
            r = json.load(open(p))
            if "error" in r:
                out.append(f"| {t} | ERROR | | | |")
                continue
            out.append(
                f"| {t} | {r['t_compute_s']:.4g} | {r['t_memory_s']:.4g} "
                f"| {r['t_collective_s']:.4g} | {r['bottleneck']} |"
            )
        out.append("")
    return "\n".join(out)


def dryrun_bytes_markdown() -> str:
    """Per-device argument/temp bytes, train_4k, both meshes: shows the
    pod axis sharding the state (deliverable-e record)."""
    out = ["| arch | mesh | args GB/dev | temp GB/dev | HLO GFLOPs/dev |",
           "|---|---|---|---|---|"]
    for p in sorted(glob.glob(os.path.join(RES, "*__train_4k__*baseline.json"))):
        r = json.load(open(p))
        if r.get("skipped") or "error" in r:
            continue
        ma = r["memory_analysis"]
        out.append(
            f"| {r['arch']} | {r['mesh']} "
            f"| {ma['argument_bytes']/1e9:.2f} "
            f"| {(ma['temp_bytes'] or 0)/1e9:.2f} "
            f"| {r['hlo_flops_per_dev']/1e9:.0f} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(roofline_markdown())
    print()
    print(perf_markdown())
