"""Paper Figs. 4/5: number of shared exponents k -- SpMV speed + maxAbsErr.

GSE-SEM SpMV (head only) vs FP64 SpMV for k in {2,4,8,16,32,64}: the paper
shows error falls monotonically with k while speed peaks near k=8.  On
this CPU container wall-clock speedups are a proxy; the byte ratio
(2+4)/(8+4) per nnz is the architectural constant that holds on TPU.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.sparse.spmv import spmv, spmv_gse


def run() -> dict:
    out = {}
    # diag-rescaled variants mirror SuiteSparse's unequilibrated matrices
    # (per-row exponents spread over ~8 binades -> k visibly controls error)
    suite = {
        "poisson2d_64_rs": G.diag_rescale(G.poisson2d(64), 8.0, 1),
        "random_spd_5k_rs": G.diag_rescale(G.random_spd(5000, seed=2), 8.0, 2),
        "circuit_5k_rs": G.diag_rescale(G.circuit_like(4960, seed=5), 8.0, 3),
        "convdiff_48_rs": G.diag_rescale(
            G.convection_diffusion_2d(48, beta=50.0), 8.0, 4),
    }
    for name, a in suite.items():
        x = jnp.ones((a.shape[1],), jnp.float64)  # paper sets x = 1
        ref = np.asarray(spmv(a, x))
        t64 = time_fn(lambda: spmv(a, x))
        emit(f"fig45/{name}/fp64", t64, f"nnz={a.nnz}")
        for k in (2, 4, 8, 16, 32, 64):
            g = pack_csr(a, k=k)
            y = np.asarray(spmv_gse(g, x, tag=1))
            err = float(np.abs(y - ref).max())
            t = time_fn(lambda g=g: spmv_gse(g, x, tag=1))
            # value+col stream: (2 head + 4 colpak) vs (8 f64 + 4 col)
            bytes_ratio = (g.nbytes(1) + 4 * a.nnz) / (a.nnz * 12)
            out[(name, k)] = dict(err=err, us=t, speedup=t64 / t,
                                  bytes_ratio=bytes_ratio)
            emit(f"fig45/{name}/k{k}", t,
                 f"maxAbsErr={err:.3e} speedup={t64/t:.2f} "
                 f"bytes_ratio={bytes_ratio:.3f}")
    # monotone error check (Fig 5 claim)
    for name in suite:
        errs = [out[(name, k)]["err"] for k in (2, 8, 64)]
        emit(f"fig45/{name}/monotone", 0.0,
             f"err_k2={errs[0]:.2e} >= err_k8={errs[1]:.2e} >= "
             f"err_k64={errs[2]:.2e}: {errs[0] >= errs[1] >= errs[2]}")
    return out


if __name__ == "__main__":
    run()
