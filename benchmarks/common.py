"""Shared benchmark utilities: timing + CSV emission.

Imported by every benchmark module -- enables float64 FIRST (the paper's
reference arithmetic; without it everything silently degrades to f32 and
the format-comparison errors drown in accumulation noise).

ALL benchmark timing routes through ``repro.perf.timing`` (PR 7): best-of-k
minimum with ``block_until_ready`` on every output.  The pre-PR-7 median
estimator tracked host noise instead of kernel cost -- it is what made
``gse_h`` look slower than the fp64 baseline in BENCH_spmv.json
(DESIGN.md §15).
"""
from __future__ import annotations

import datetime
import subprocess
from typing import Callable

import jax

jax.config.update("jax_enable_x64", True)

from repro.obs import metrics as OM  # noqa: E402  (import after x64 setup)
from repro.perf import timing  # noqa: E402


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Best-of-``iters`` wall time (us) of jitted fn (min over runs,
    every output blocked on)."""
    return timing.best_seconds(fn, *args, iters=iters, warmup=warmup) * 1e6


def timed(fn: Callable, *args, iters: int = 2, warmup: int = 1,
          label: str | None = None, **kwargs):
    """(output, best_seconds) of ``fn`` -- the shared helper for solver
    benchmarks that need the result AND the time (fig89, robust_bench).

    With ``label``, the first call (trace + compile) and the steady-state
    best are recorded separately in the metrics registry (DESIGN.md §16):
    ``repro_bench_compile_seconds{case=label}`` gets ``max(first - best,
    0)`` and ``repro_bench_execute_seconds{case=label}`` gets the best --
    so BENCH_obs.json can show how much of a benchmark's wall clock was
    XLA compilation rather than execution.
    """
    if label is None:
        return timing.measure(fn, *args, iters=iters, warmup=warmup,
                              **kwargs)
    out, first, best = timing.measure_split(fn, *args, iters=iters,
                                            warmup=warmup, **kwargs)
    OM.REGISTRY.histogram(
        "repro_bench_compile_seconds",
        "Estimated first-call compile time (first - steady best, >= 0).",
        labelnames=("case",),
    ).labels(case=label).observe(max(first - best, 0.0))
    OM.REGISTRY.histogram(
        "repro_bench_execute_seconds",
        "Steady-state best-of-k execution time.",
        labelnames=("case",),
    ).labels(case=label).observe(best)
    return out, best


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.2f},{derived}", flush=True)


def provenance() -> dict:
    """Provenance header stamped into every BENCH_*.json (DESIGN.md §16).

    Identifies WHAT produced a benchmark artifact: git commit, jax/jaxlib
    versions, the device kind the run saw, the persisted host roofline
    probe (``perf.tunecache.host_entry``), and a UTC timestamp.  Every
    field degrades to None rather than raising -- benchmarks must emit
    even from a tarball checkout with no git.
    """
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10,
        ).stdout.strip() or None
    except Exception:
        sha = None
    try:
        import jaxlib
        jaxlib_version = jaxlib.__version__
    except Exception:
        jaxlib_version = None
    try:
        dev = jax.devices()[0]
        device_kind = dev.device_kind
        device_count = jax.device_count()
    except Exception:
        device_kind = None
        device_count = None
    try:
        from repro.perf import tunecache
        host = tunecache.host_entry()
    except Exception:
        host = None
    return {
        "git_sha": sha,
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib_version,
        "backend": jax.default_backend(),
        "device_kind": device_kind,
        "device_count": device_count,
        "host_roofline": host,
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
