"""Shared benchmark utilities: timing + CSV emission.

Imported by every benchmark module -- enables float64 FIRST (the paper's
reference arithmetic; without it everything silently degrades to f32 and
the format-comparison errors drown in accumulation noise).

ALL benchmark timing routes through ``repro.perf.timing`` (PR 7): best-of-k
minimum with ``block_until_ready`` on every output.  The pre-PR-7 median
estimator tracked host noise instead of kernel cost -- it is what made
``gse_h`` look slower than the fp64 baseline in BENCH_spmv.json
(DESIGN.md §15).
"""
from __future__ import annotations

from typing import Callable

import jax

jax.config.update("jax_enable_x64", True)

from repro.perf import timing  # noqa: E402  (import after x64 setup)


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Best-of-``iters`` wall time (us) of jitted fn (min over runs,
    every output blocked on)."""
    return timing.best_seconds(fn, *args, iters=iters, warmup=warmup) * 1e6


def timed(fn: Callable, *args, iters: int = 2, warmup: int = 1, **kwargs):
    """(output, best_seconds) of ``fn`` -- the shared helper for solver
    benchmarks that need the result AND the time (fig89, robust_bench)."""
    return timing.measure(fn, *args, iters=iters, warmup=warmup, **kwargs)


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.2f},{derived}", flush=True)
