"""Shared benchmark utilities: timing + CSV emission.

Imported by every benchmark module -- enables float64 FIRST (the paper's
reference arithmetic; without it everything silently degrades to f32 and
the format-comparison errors drown in accumulation noise).
"""
from __future__ import annotations

import time
from typing import Callable

import jax

jax.config.update("jax_enable_x64", True)


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall time (us) of jitted fn over ``iters`` runs."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.2f},{derived}", flush=True)
