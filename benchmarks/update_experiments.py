"""Splice the regenerated roofline table into EXPERIMENTS.md."""
import os
import re
import sys

sys.path.insert(0, os.path.dirname(__file__))
from emit_experiments_tables import roofline_markdown  # noqa: E402

EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")

src = open(EXP).read()
table = roofline_markdown()
marker = "<!-- ROOFLINE_TABLE -->"
if marker in src:
    src = src.replace(marker, marker + "\n\n" + table)
else:
    # replace the previously spliced table (between marker-start comments)
    src = re.sub(
        r"(<!-- ROOFLINE_TABLE_START -->).*?(<!-- ROOFLINE_TABLE_END -->)",
        r"\1\n" + table + r"\n\2", src, flags=re.S)
open(EXP, "w").write(src)
print("spliced roofline table:", len(table.splitlines()), "rows")
