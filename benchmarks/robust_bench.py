"""Robustness bench: fault detection, escalation recovery, guard overhead.

Three headline numbers for the guardrail stack (DESIGN.md §14):

  * ``detection_rate``  -- fraction of seeded silent-corruption injections
    caught by the integrity machinery: CRC32 segment checksums on packed
    GSE operands (``robustness.faults``), checksum-verified entries of the
    ``kernels/ops._cached_pack`` LRU, and the position-weighted u32 wire
    checksums riding alongside halo payloads (``distributed.wire``).
  * ``recovery_rate``   -- fraction of deterministic low-tag operator
    faults (indefinite / NaN-producing at tags <= fail_tag) that the
    guard + tag-escalation ladder detects AND solves through to a
    converged, finite solution at a higher rung.
  * ``overhead_ratio``  -- clean-path wall-time ratio of the guarded vs
    unguarded stepped CG loop on the fig89 smoke matrix (the guards
    compile into the same jitted iteration; the acceptance bar is <= 10%).

Wire detection needs >= 2 devices (``run.py --robust`` forces two host
CPU devices when XLA_FLAGS is unset); with one device that family is
skipped and reported as such.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed

import jax  # noqa: E402  (common enables x64 first)
import jax.numpy as jnp

_PARAMS = None  # built lazily: MonitorParams import must follow x64 setup


def _params():
    global _PARAMS
    if _PARAMS is None:
        from repro.core.precision import MonitorParams
        _PARAMS = MonitorParams(t=40, l=60, m=30, rsd_limit=0.5,
                                reldec_limit=0.45)
    return _PARAMS


def _operand(n=16, k=8):
    from repro.sparse import generators as G
    from repro.sparse.csr import pack_csr

    csr = G.poisson2d(n)
    return csr, pack_csr(csr, k=k)


def detection_pack(seeds=(0, 1, 2)) -> dict:
    """Seeded bit-flips in every packed GSE segment vs the CRC32 refs."""
    from repro.robustness.faults import (GSECSR_SEGMENTS, corrupt_gsecsr,
                                         gsecsr_checksums, verify_gsecsr)

    _, g = _operand()
    ref = gsecsr_checksums(g)
    cases = {}
    for target in GSECSR_SEGMENTS:
        for seed in seeds:
            bad = corrupt_gsecsr(g, target, seed)
            cases[f"pack/{target}/s{seed}"] = target in verify_gsecsr(bad, ref)
    return cases


def detection_pack_cache(seeds=(0, 1, 2)) -> dict:
    """Corrupt a memoized ``_cached_pack`` entry (keeping its stored
    checksum); the next hit must count a ``corrupt`` detect-and-repack."""
    from repro.kernels.ops import PACK_STATS, sell_pack_gsecsr
    from repro.robustness.faults import corrupt_pack_cache

    _, g = _operand()
    sell_pack_gsecsr(g)  # populate the entry under test
    cases = {}
    for seed in seeds:
        assert corrupt_pack_cache(g, seed=seed)
        before = PACK_STATS["corrupt"]
        sell_pack_gsecsr(g)  # hit: verify -> detect -> repack
        cases[f"cache/sell/s{seed}"] = PACK_STATS["corrupt"] == before + 1
    return cases


def detection_wire(seeds=(0, 1, 2)) -> dict | None:
    """Wire-checksum detection of in-flight halo corruption.

    Runs ``halo_all_gather(..., check=True)`` inside a 2-shard shard_map
    with a seeded fault hook corrupting one payload segment; the
    receiver-side checksum compare must go False.  Returns None (skipped)
    with fewer than 2 devices.
    """
    if jax.device_count() < 2:
        return None
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    from repro.distributed.wire import halo_all_gather, set_wire_fault
    from repro.robustness.faults import make_wire_fault

    mesh = Mesh(np.array(jax.devices()[:2]), ("sh",))
    full = jnp.asarray(np.random.default_rng(3).normal(size=64))

    def ok_under(hook, tag, wire) -> bool:
        fn = shard_map(
            lambda bnd: halo_all_gather(bnd, "sh", tag=tag, wire=wire,
                                        check=True)[1],
            mesh=mesh, in_specs=P("sh"), out_specs=P(), check_rep=False,
        )
        set_wire_fault(hook)
        try:
            return bool(fn(full))
        finally:
            set_wire_fault(None)

    combos = [("gse", 1, "head"), ("gse", 1, "table"),
              ("gse", 2, "head"), ("gse", 2, "tail1"), ("gse", 2, "table"),
              ("exact", 3, "raw"), ("gse", 3, "raw")]
    cases = {}
    for wire, tag, target in combos:
        # clean-path sanity: the checksum must PASS without a fault
        cases[f"wire/{wire}-t{tag}/clean"] = ok_under(None, tag, wire)
        for seed in seeds:
            hook = make_wire_fault(target, seed)
            cases[f"wire/{wire}-t{tag}/{target}/s{seed}"] = \
                not ok_under(hook, tag, wire)
    return cases


def recovery_cases(tol=1e-8, maxiter=3000) -> dict:
    """Low-tag operator faults solved through by guard + escalation."""
    from repro.robustness.faults import make_tag_fault_operator
    from repro.robustness.guards import HEALTH_OK
    from repro.solvers.cg import solve_cg, solve_pcg
    from repro.solvers.precond import make_jacobi
    from repro.sparse.spmv import spmv

    csr, g = _operand()
    rng = np.random.default_rng(11)
    b = spmv(csr, jnp.asarray(rng.normal(size=csr.shape[1])))
    jac = make_jacobi(csr)

    def judge(res, fail_tag):
        x_fin = bool(jnp.isfinite(jnp.vdot(res.x, res.x)))
        return {
            "recovered": bool(res.converged) and x_fin
                         and int(res.health) == HEALTH_OK
                         and int(res.tag) > fail_tag,
            "tripped": int(res.trip_iter) >= 0,
            "final_tag": int(res.tag),
            "iters": int(res.iters),
            "relres": float(res.relres),
        }

    cases = {}
    for mode in ("indefinite", "nan"):
        for fail_tag in (1, 2):
            op = make_tag_fault_operator(g, mode, fail_tag=fail_tag)
            res = solve_cg(op, b, tol=tol, maxiter=maxiter, params=_params())
            cases[f"cg/{mode}/fail{fail_tag}"] = judge(res, fail_tag)
    op = make_tag_fault_operator(g, "indefinite", fail_tag=1)
    res = solve_pcg(op, b, jac, tol=tol, maxiter=maxiter, params=_params())
    cases["pcg/indefinite/fail1"] = judge(res, 1)
    return cases


def overhead(n=24, tol=1e-8, maxiter=2000, repeats=3) -> dict:
    """Guards-on vs guards-off wall time of the clean fused stepped CG."""
    from repro.robustness.guards import DEFAULT_GUARDS
    from repro.solvers.cg import solve_cg
    from repro.sparse.spmv import spmv

    csr, g = _operand(n=n)
    rng = np.random.default_rng(7)
    b = spmv(csr, jnp.asarray(rng.normal(size=csr.shape[1])))

    def run_once(guards):
        return solve_cg(g, b, tol=tol, maxiter=maxiter, params=_params(),
                        guards=guards, recover=False)

    out = {}
    for name, guards in (("off", None), ("on", DEFAULT_GUARDS)):
        # Shared best-of-k min timing (benchmarks.common.timed).
        res, best = timed(run_once, guards, iters=repeats, warmup=1)
        out[f"guards_{name}_s"] = best
        out[f"guards_{name}_iters"] = int(res.iters)
    out["ratio"] = out["guards_on_s"] / out["guards_off_s"]
    return out


def _rate(cases: dict) -> float:
    vals = [v["recovered"] if isinstance(v, dict) else v
            for v in cases.values()]
    return float(np.mean([bool(v) for v in vals])) if vals else 0.0


def run(quick: bool = False) -> dict:
    """Full robustness sweep; returns the BENCH_robust.json payload."""
    det = {}
    det.update(detection_pack())
    det.update(detection_pack_cache())
    wire = detection_wire()
    wire_skipped = wire is None
    if wire is not None:
        det.update(wire)
    rec = recovery_cases()
    ovh = overhead(n=16 if quick else 24,
                   maxiter=1500 if quick else 2000)

    results = {
        "detection": {
            "cases": {k: bool(v) for k, v in det.items()},
            "rate": _rate(det),
            "n_cases": len(det),
            "wire_skipped": wire_skipped,
        },
        "recovery": {
            "cases": rec,
            "rate": _rate(rec),
            "n_cases": len(rec),
        },
        "overhead": ovh,
    }
    emit("robust_detection", 0.0,
         f"rate={results['detection']['rate']:.3f}/"
         f"{results['detection']['n_cases']}cases"
         + (" (wire skipped: 1 device)" if wire_skipped else ""))
    emit("robust_recovery", 0.0,
         f"rate={results['recovery']['rate']:.3f}/"
         f"{results['recovery']['n_cases']}cases")
    emit("robust_overhead", ovh["guards_on_s"] * 1e6,
         f"ratio={ovh['ratio']:.3f} vs off={ovh['guards_off_s'] * 1e6:.0f}us")
    return results


if __name__ == "__main__":
    import json

    print(json.dumps(run(quick=True), indent=2, sort_keys=True))
