"""Observability bench: flight-recorder identity, overhead, serve replay.

Three headline checks for the observability stack (DESIGN.md §16):

  * ``bit_identity``  -- recorder-on vs recorder-off solves return
    bit-identical ``x``/``iters`` across {CG fused, CG+guards, PCG,
    GMRES, batched, sharded}: the flight ring is observation-only state
    threaded through the same jitted loop, never feeding back into the
    arithmetic.  Every on-case log is also cross-checked against the
    solver's own monitor/guard report (``flight.assert_consistent``).
  * ``overhead``      -- clean-path wall-time ratio of the stepped CG
    loop with flight recording + span tracing active vs fully off (the
    acceptance bar is <= 1.10, gated in ``run.py --obs``).
  * ``serve``         -- a replayed :class:`SolverService` workload read
    back entirely from the metrics registry: p50/p95/p99 flush latency,
    bytes/request, queue-depth gauge, and the event counters.

The sharded identity case needs >= 2 devices (``run.py --obs`` forces
two host CPU devices when XLA_FLAGS is unset); with one device it is
skipped and reported as such.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed

import jax  # noqa: E402  (common enables x64 first)
import jax.numpy as jnp

_PARAMS = None  # built lazily: MonitorParams import must follow x64 setup


def _step_params():
    """Forced-stepping monitor: C2 fires at every due check, so the tag
    walks 1 -> 2 -> 3 at deterministic iterations (10 and 15) -- the
    telemetry columns under test are guaranteed non-trivial."""
    global _PARAMS
    if _PARAMS is None:
        from repro.core.precision import MonitorParams
        _PARAMS = MonitorParams(t=10, l=10, m=5, rsd_limit=0.5,
                                reldec_limit=2.0)
    return _PARAMS


def _operand(n=12, k=8):
    from repro.sparse import generators as G
    from repro.sparse.csr import pack_csr

    csr = G.poisson2d(n)
    return csr, pack_csr(csr, k=k)


def _log_of(state):
    from repro.obs import flight as OF
    return OF.FlightLog.from_state(state)


def bit_identity(tol=1e-10, maxiter=400) -> dict:
    """Recorder-on vs recorder-off bitwise identity per solver family.

    Each case solves twice -- ``flight=None`` and ``flight=FlightParams``
    -- and demands ``np.array_equal`` on the solution and the iteration
    count, then validates the on-case telemetry against the result's own
    monitor fields.  Returns per-case {identical, consistent, rows,
    switch_iters, iters}.
    """
    from repro.obs import flight as OF
    from repro.robustness.guards import DEFAULT_GUARDS
    from repro.solvers.batched import solve_cg_batched
    from repro.solvers.cg import solve_cg, solve_pcg
    from repro.solvers.gmres import solve_gmres
    from repro.solvers.operators import make_gse_operator
    from repro.solvers.precond import make_jacobi

    csr, g = _operand()
    n = csr.shape[0]
    rng = np.random.default_rng(3)
    b = jnp.asarray(rng.standard_normal(n))
    fp = OF.FlightParams(capacity=256)
    params = _step_params()
    pre = make_jacobi(csr)

    def entry(off, on, log, res_on, consistent=None):
        ident = (np.array_equal(np.asarray(off.x), np.asarray(on.x))
                 and int(np.asarray(off.iters).max())
                 == int(np.asarray(on.iters).max()))
        if consistent is None:
            try:
                OF.assert_consistent(log, res_on)
                consistent = True
            except AssertionError:
                consistent = False
        return {
            "identical": bool(ident),
            "consistent": bool(consistent),
            "rows": len(log),
            "iters": int(np.asarray(on.iters).max()),
            "switch_iters": [int(v) for v in log.switch_iters()],
        }

    cases = {}

    # CG, fused fast path (guards off) and guarded generic path.
    for name, gu in (("cg_fused", None), ("cg_guarded", DEFAULT_GUARDS)):
        off = solve_cg(g, b, tol=tol, maxiter=maxiter, params=params,
                       guards=gu, recover=False)
        on = solve_cg(g, b, tol=tol, maxiter=maxiter, params=params,
                      guards=gu, recover=False, flight=fp)
        cases[name] = entry(off, on, _log_of(on.flight), on)

    off = solve_pcg(g, b, pre, tol=tol, maxiter=maxiter, params=params,
                    recover=False)
    on = solve_pcg(g, b, pre, tol=tol, maxiter=maxiter, params=params,
                   recover=False, flight=fp)
    cases["pcg"] = entry(off, on, _log_of(on.flight), on)

    op = make_gse_operator(g)
    off = solve_gmres(op, b, tol=tol, restart=25, maxiter=maxiter,
                      params=params, recover=False)
    on = solve_gmres(op, b, tol=tol, restart=25, maxiter=maxiter,
                     params=params, recover=False, flight=fp)
    cases["gmres"] = entry(off, on, _log_of(on.flight), on)

    # Batched: per-column rings; column 0's log must ALSO match the
    # single-RHS solve of the same column bit-for-bit.
    B = jnp.asarray(rng.standard_normal((n, 3)))
    boff = solve_cg_batched(g, B, tol=tol, maxiter=maxiter, params=params)
    bon = solve_cg_batched(g, B, tol=tol, maxiter=maxiter, params=params,
                           flight=fp)
    col0 = _log_of(OF.split_batched(bon.flight)[0])
    single = solve_cg(g, B[:, 0], tol=tol, maxiter=maxiter, params=params,
                      recover=False, flight=fp)
    slog = _log_of(single.flight)
    match0 = (np.array_equal(col0.it, slog.it)
              and np.array_equal(col0.tag, slog.tag)
              and np.array_equal(col0.relres, slog.relres))
    cases["batched"] = entry(boff, bon, col0, bon, consistent=bool(match0))

    cases["sharded"] = _bit_identity_sharded(b, tol, maxiter, fp, params)
    return cases


def _bit_identity_sharded(b, tol, maxiter, fp, params) -> dict:
    """Sharded CG: replicated flight ring vs the single-device log."""
    if jax.device_count() < 2:
        return {"skipped": "needs >= 2 devices"}
    from repro.distributed.partition import partition_gsecsr
    from repro.obs import flight as OF
    from repro.solvers.cg import solve_cg
    from repro.solvers.sharded import solve_cg_sharded

    _, g = _operand()
    part = partition_gsecsr(g, min(jax.device_count(), 2))
    off = solve_cg_sharded(part, b, tol=tol, maxiter=maxiter, params=params)
    on = solve_cg_sharded(part, b, tol=tol, maxiter=maxiter, params=params,
                          flight=fp)
    log = _log_of(on.flight)
    try:
        OF.assert_consistent(log, on)
        consistent = True
    except AssertionError:
        consistent = False
    # The psum'd telemetry must equal the single-device recording exactly
    # (exact wire: identical arithmetic, identical flight rows).
    ref = solve_cg(g, b, tol=tol, maxiter=maxiter, params=params, flight=fp)
    rlog = _log_of(ref.flight)
    consistent = consistent and np.array_equal(log.it, rlog.it) \
        and np.array_equal(log.tag, rlog.tag)
    return {
        "identical": bool(np.array_equal(np.asarray(off.x), np.asarray(on.x))
                          and int(off.iters) == int(on.iters)),
        "consistent": bool(consistent),
        "rows": len(log),
        "iters": int(on.iters),
        "switch_iters": [int(v) for v in log.switch_iters()],
    }


def overhead(n=24, tol=1e-8, maxiter=2000, repeats=3) -> dict:
    """Recorder+tracer-on vs fully-off wall time of the clean stepped CG.

    Mirrors ``robust_bench.overhead`` (same operand, same best-of-k min
    timing): the flight ring compiles into the same jitted loop, and the
    host-side spans wrap only the solve entry point -- the bar is <= 1.10.
    """
    from repro.core.precision import MonitorParams
    from repro.obs import flight as OF
    from repro.obs import trace as OT
    from repro.solvers.cg import solve_cg
    from repro.sparse.spmv import spmv

    csr, g = _operand(n=n)
    rng = np.random.default_rng(7)
    b = spmv(csr, jnp.asarray(rng.normal(size=csr.shape[1])))
    params = MonitorParams(t=40, l=60, m=30, rsd_limit=0.5,
                           reldec_limit=0.45)
    fp = OF.FlightParams(capacity=1024)

    def run_once(flight):
        return solve_cg(g, b, tol=tol, maxiter=maxiter, params=params,
                        recover=False, flight=flight)

    out = {}
    res, best = timed(run_once, None, iters=repeats, warmup=1,
                      label="obs_overhead_off")
    out["obs_off_s"] = best
    out["obs_off_iters"] = int(res.iters)
    tracer = OT.Tracer()
    OT.install(tracer)
    try:
        res, best = timed(run_once, fp, iters=repeats, warmup=1,
                          label="obs_overhead_on")
    finally:
        OT.uninstall()
    out["obs_on_s"] = best
    out["obs_on_iters"] = int(res.iters)
    out["trace_events"] = len(tracer.events)
    out["ratio"] = out["obs_on_s"] / out["obs_off_s"]
    return out


def serve_replay(requests=12, slots=4, waves=3) -> dict:
    """Replay a SolverService workload; read it all back from the registry.

    Submits ``requests`` solves against one registered operator in
    ``waves`` flush waves, then reports ONLY what the metrics layer
    recorded: flush-latency and bytes/request histogram summaries
    (p50/p95/p99), the queue-depth gauge, and the per-service counters --
    proving the exposition path carries the serving story end to end.
    """
    from repro.launch.solver_serve import SolverService
    from repro.obs import metrics as OM

    csr, _ = _operand()
    n = csr.shape[0]
    svc = SolverService(slots=slots, params=_step_params(), maxiter=800)
    svc.register("op", csr, k=8)
    rng = np.random.default_rng(11)
    per_wave = max(1, requests // waves)
    rids, depth_peak = [], 0
    for _ in range(waves):
        for _ in range(per_wave):
            rids.append(svc.submit("op", rng.standard_normal(n), tol=1e-8))
        depth_peak = max(depth_peak, int(svc.queue_depth.value))
        reports = svc.flush()
        assert all(r.converged for r in reports.values()), "replay solve"
    flush_lat = svc.flush_latency.summary()
    req_bytes = svc.request_bytes.summary()
    reg = OM.REGISTRY.to_json()
    return {
        "requests": len(rids),
        "waves": waves,
        "queue_depth_peak": depth_peak,
        "queue_depth_final": int(svc.queue_depth.value),
        "flush_latency_s": flush_lat,
        "request_bytes": req_bytes,
        "stats": dict(svc.stats),
        "registry_series": len(reg["metrics"]),
    }


def run(quick: bool = False) -> dict:
    """Full observability sweep; returns the BENCH_obs.json payload."""
    ident = bit_identity()
    ovh = overhead(n=16 if quick else 24,
                   maxiter=1500 if quick else 2000)
    serve = serve_replay(requests=6 if quick else 12)
    from repro.obs import metrics as OM
    return {
        "bit_identity": ident,
        "overhead": ovh,
        "serve": serve,
        "metrics": OM.REGISTRY.to_json(),
    }
