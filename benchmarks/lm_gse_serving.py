"""Beyond-paper bridge: GSE-SEM weight serving for LM matmuls.

One stored copy -> three serving precisions (paper's storage/compute
decoupling at LM scale): bytes-per-weight and matmul error vs bf16/fp16
for a real (smoke-scale) transformer's weight matrices + Pallas kernel
timing (interpret mode; structural on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro import configs
from repro.core import gse
from repro.models import transformer as T
from repro.quant import gse_tensor as Q


def run() -> dict:
    cfg = configs.get_config("qwen3_4b", smoke=True)
    params, _ = T.init_params(cfg, jax.random.key(0))
    w = params["layers"]["mlp"]["w_up"][0]  # (d, ff) real init stats
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, w.shape[0])), jnp.float32)
    exact = np.asarray(x, np.float64) @ np.asarray(w, np.float64)

    q = Q.quantize_tree({"w": w}, min_size=16)["w"]
    out = {}
    for label, (y, nbytes) in {
        "f32": (x @ w, w.nbytes),
        "bf16": (x @ w.astype(jnp.bfloat16).astype(jnp.float32),
                 w.size * 2),
        "fp16": (x @ w.astype(jnp.float16).astype(jnp.float32),
                 w.size * 2),
        "gse_t1": (Q.gse_linear(x, q, tag=1, dtype=jnp.float32),
                   q.nbytes(1)),
        "gse_t2": (Q.gse_linear(x, q, tag=2, dtype=jnp.float32),
                   q.nbytes(2)),
        "gse_t3": (Q.gse_linear(x, q, tag=3, dtype=jnp.float32),
                   q.nbytes(3)),
    }.items():
        err = float(np.abs(np.asarray(y, np.float64) - exact).max()
                    / np.abs(exact).max())
        out[label] = dict(err=err, bytes=nbytes)
        emit(f"lm_serving/{label}", 0.0,
             f"rel_err={err:.3e} bytes_per_weight={nbytes/w.size:.2f}")

    # Pallas fused dequant-matmul (interpret): correctness + proxy timing
    from repro.kernels import ops

    p = gse.pack(np.asarray(w, np.float64), 8)
    t_us = time_fn(lambda: ops.gse_matmul(x[:8], p, tag=1), iters=3,
                   warmup=1)
    emit("lm_serving/pallas_gse_matmul_t1", t_us, "interpret-mode timing")
    return out


if __name__ == "__main__":
    run()
