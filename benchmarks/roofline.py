"""Roofline table from the dry-run artifacts (deliverable g).

Reads dryrun_results/*.json (written by repro.launch.dryrun) and prints
the per-(arch x shape x mesh) three-term roofline.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "dryrun_results")


def run() -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        name = f"roofline/{r['arch']}/{r['shape']}/{r.get('mesh','?')}/" \
               f"{r.get('tag','baseline')}"
        if r.get("skipped"):
            emit(name, 0.0, f"SKIP: {r['reason'][:60]}")
            continue
        if "error" in r:
            emit(name, 0.0, f"ERROR: {r['error'][:80]}")
            continue
        rows.append(r)
        emit(
            name, 0.0,
            f"comp={r['t_compute_s']:.4f}s mem={r['t_memory_s']:.4f}s "
            f"coll={r['t_collective_s']:.4f}s bound={r['bottleneck']} "
            f"frac={r['roofline_fraction']:.3f} "
            f"useful={r['model_over_hlo_flops']:.2f}"
        )
    return rows


if __name__ == "__main__":
    run()
