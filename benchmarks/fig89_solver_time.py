"""Paper Figs. 8/9: end-to-end solver wall time + speedup over FP64.

Includes GSE-SEM* (paper Eq. 7): the projected time if format conversion
were free (hardware GSE-SEM support), computed as
TIME_fp16 / ITERS_fp16 * ITERS_gse.

Modeled speedups come from the containers' ``bytes_touched`` accounting:
the stepped GSE runs charge every iteration the bytes of the precision
tag it actually ran at (split by the recorded switch iterations) instead
of a constant per-format stream estimate.  The CG "gse" row exercises the
fused iteration path (``solve_cg`` with the ``GSECSR`` operand).

``nrhs > 1`` adds batched multi-RHS rows (``solve_cg_batched`` over the
same shared GSECSR): the byte model charges matrix segment bytes ONCE per
iteration and vector bytes per active column
(``iteration_stream_bytes(..., nrhs=...)``), so bytes/iteration for
nrhs=4 sits well under 4x -- and, on the matrices whose nnz/row carries
the stream, under 2x -- of the nrhs=1 figure (DESIGN.md §11).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core.precision import MonitorParams
from repro.sparse import generators as G
from repro.sparse.csr import iteration_stream_bytes, pack_csr
from repro.solvers import (
    batched_run_bytes,
    make_fixed_operator,
    make_gse_operator,
    make_jacobi,
    make_spai0,
    solve_cg,
    solve_cg_batched,
    solve_gmres,
    solve_pcg,
)

_PARAMS = MonitorParams(t=40, l=60, m=30, rsd_limit=0.5, reldec_limit=0.45)

_PRECOND_FACTORY = {"jacobi": make_jacobi, "spai0": make_spai0}


def _timed(solver, op, b, **kw):
    # Shared best-of-k min timing (benchmarks.common.timed -> perf.timing):
    # warm compile + 2 timed runs, every result blocked on.
    return timed(solver, op, b, iters=2, warmup=1, **kw)


def _gse_run_bytes(g, iters, switch_iters, precond=None, layout=None):
    """Modeled matrix(+preconditioner)-stream bytes of a stepped run: each
    iteration is charged ``iteration_stream_bytes(g, tag, precond)`` for
    the tag it actually ran at, using the recorded switch iterations to
    split the trajectory -- so preconditioner bytes follow the schedule
    too (a tag-1 iteration pays 2 B per stored preconditioner entry).

    ``layout`` (a ``GSESellC``/``ELLLayout``) switches every iteration to
    the padding-honest account -- actual padded slots instead of nnz only
    (DESIGN.md §12) -- so skewed-matrix trajectories stop under-reporting
    what the packed kernels really stream."""
    iters = int(iters)
    sw = np.asarray(switch_iters)
    t2 = int(sw[0]) if sw[0] >= 0 else iters  # first tag-2 iteration
    t3 = int(sw[1]) if sw[1] >= 0 else iters  # first tag-3 iteration
    n1 = max(min(t2, iters), 0)
    n3 = max(iters - t3, 0)
    n2 = max(iters - n1 - n3, 0)
    return (n1 * iteration_stream_bytes(g, 1, precond, layout=layout)
            + n2 * iteration_stream_bytes(g, 2, precond, layout=layout)
            + n3 * iteration_stream_bytes(g, 3, precond, layout=layout))


def batched_case(a, g, nrhs: int, params=_PARAMS, tol=1e-6,
                 maxiter=1500, seed=0) -> dict:
    """One batched multi-RHS stepped-CG measurement over a shared GSECSR.

    Returns wall time, per-column iters/relres/switches, the batched
    byte model (matrix bytes once per iteration), and the per-iteration
    byte ratio vs the nrhs=1 figure -- the quantity the acceptance bar
    bounds (< 2x for nrhs=4 on stream-dominated matrices).
    """
    from repro.sparse.spmv import spmv

    rng = np.random.default_rng(seed)
    b = jnp.stack(
        [jnp.asarray(np.asarray(spmv(a, jnp.asarray(
            rng.normal(size=a.shape[1]))))) for _ in range(nrhs)],
        axis=1,
    )
    kw = dict(tol=tol, maxiter=maxiter, params=params)
    res, t = _timed(solve_cg_batched, g, b, **kw)
    iters = np.asarray(res.iters)
    run_bytes = batched_run_bytes(g, res.iters, res.switch_iters)
    # Per-iteration figures at the dominant (tag-1) stream for the
    # headline ratio; the trajectory-split totals are reported alongside.
    per_it = {m: iteration_stream_bytes(g, 1, nrhs=m) for m in (1, nrhs)}
    return dict(
        t=t,
        nrhs=nrhs,
        iters=iters.tolist(),
        relres=np.asarray(res.relres).tolist(),
        converged=np.asarray(res.converged).tolist(),
        switch_iters=np.asarray(res.switch_iters).tolist(),
        run_bytes=int(run_bytes),
        bytes_per_iter_nrhs=int(per_it[nrhs]),
        bytes_per_iter_1=int(per_it[1]),
        per_iter_ratio=per_it[nrhs] / per_it[1],
        naive_nx_bytes=int(per_it[1]) * int(iters.sum()),
    )


def dist_case(a, g, shards: int, wire: str = "gse", params=_PARAMS,
              tol=1e-6, maxiter=1500, seed=0) -> dict:
    """One distributed stepped-CG measurement over a row-sharded operator
    (DESIGN.md §13).

    Runs the fully-sharded loop twice -- ``wire="exact"`` for the parity
    contract against single-device ``solve_cg`` (same iterate count,
    trajectories to ~machine precision) and the requested ``wire`` for
    the headline -- and reports the distributed byte model: per-shard
    matrix streams (which sum EXACTLY to the single-device
    ``iteration_stream_bytes``) plus the tag-aware halo wire ladder.
    """
    from repro.distributed.partition import partition_gsecsr
    from repro.sparse.spmv import spmv

    rng = np.random.default_rng(seed)
    b = jnp.asarray(np.asarray(spmv(a, jnp.asarray(
        rng.normal(size=a.shape[1])))))
    kw = dict(tol=tol, maxiter=maxiter, params=params)
    ref, t_ref = _timed(solve_cg, g, b, **kw)
    part = partition_gsecsr(g, shards)
    res_x, _ = _timed(solve_cg, part, b, wire="exact", **kw)
    res, t = _timed(solve_cg, part, b, wire=wire, **kw)
    scale = float(jnp.max(jnp.abs(ref.x)))
    x_maxdiff = float(jnp.max(jnp.abs(res_x.x - ref.x))) / max(scale, 1e-300)
    shard_bytes = {tag: list(part.shard_stream_bytes(tag)) for tag in
                   (1, 2, 3)}
    wire_bytes = {tag: part.halo_wire_bytes(tag, wire) for tag in (1, 2, 3)}
    sum_identity = all(
        sum(shard_bytes[tag]) + part.shared_stream_bytes()
        == iteration_stream_bytes(g, tag)
        for tag in (1, 2, 3)
    )
    return dict(
        t=t,
        t_ref=t_ref,
        shards=shards,
        wire=wire,
        iters=int(res.iters),
        relres=float(res.relres),
        converged=bool(res.converged),
        switch_iters=np.asarray(res.switch_iters).tolist(),
        ref_iters=int(ref.iters),
        ref_relres=float(ref.relres),
        exact_iters=int(res_x.iters),
        exact_x_maxdiff=x_maxdiff,
        shard_bytes=shard_bytes,
        shared_bytes=part.shared_stream_bytes(),
        halo_wire_bytes=wire_bytes,
        halo_entries=part.halo_entries,
        byte_sum_identity=sum_identity,
        iter_bytes={tag: part.iteration_stream_bytes(tag, wire)
                    for tag in (1, 2, 3)},
    )


def run(precond: str = "none", nrhs: int = 1, layout: str = "nnz",
        shards: int = 1) -> dict:
    """``layout="sell"`` switches the GSE rows' byte model to the
    padding-honest account: each case's operator is SELL-C-σ packed
    (``kernels.ops.sell_pack_gsecsr``) and every stepped iteration is
    charged the layout's ACTUAL padded slots (DESIGN.md §12) -- what the
    packed kernels really stream on skewed matrices.  The ``"nnz"``
    default keeps the encoding-only figures unchanged.

    ``shards > 1`` adds row-sharded distributed rows (``dist_case``) to
    the CG cases: the same matrix stream redistributed across shards plus
    the tag-aware halo wire ladder (DESIGN.md §13; needs that many
    devices -- ``run.py --shards`` forces host CPU devices)."""
    if layout not in ("nnz", "sell"):
        raise ValueError(f"unknown layout {layout!r}; expected 'nnz'/'sell'")
    from repro.perf import roofline as rl

    # Host roofline (persisted probe); solver rows report attainable /
    # measured time for the SpMV-dominant stream (DESIGN.md §15).
    roof = rl.host_roofline(quick=True)
    out = {}
    cases = []
    for i, (name, a) in enumerate(list(G.cg_suite(small=True).items())[:4]):
        if a is None:
            continue
        cases.append(("cg", name, a, i))
    if precond != "none":
        # The preconditioned rows earn their keep on the ill-conditioned
        # workload where unpreconditioned stepped CG stalls.
        cases.append(("cg", "illcond_32", G.ill_conditioned_spd(32, 8.0), 50))
    for i, (name, a) in enumerate(list(G.gmres_suite(small=True).items())[:3]):
        cases.append(("gmres", name, a, 100 + i))

    for kind, name, a, seed in cases:
        rng = np.random.default_rng(seed)
        from repro.sparse.spmv import spmv

        b = jnp.asarray(np.asarray(spmv(a, jnp.asarray(
            rng.normal(size=a.shape[1])))))
        g = pack_csr(a, k=8)
        solver = solve_cg if kind == "cg" else solve_gmres
        kw = dict(tol=1e-6, params=_PARAMS)
        kw["maxiter"] = 1500 if kind == "cg" else 2400

        rows = {}
        # CG takes the GSECSR directly -> fused iteration path
        # (bit-identical trajectory, fewer kernel launches).  One operator
        # per case: _solve_gmres keys its jit cache on the closure
        # identity, so the preconditioned row below must reuse it.
        gse_op = g if kind == "cg" else make_gse_operator(g)
        for label, op in {
            "fp64": make_fixed_operator(a),
            "fp16": make_fixed_operator(a, store_dtype=jnp.float16),
            "bf16": make_fixed_operator(a, store_dtype=jnp.bfloat16),
            "gse": gse_op,
        }.items():
            res, t = _timed(solver, op, b, **kw)
            rows[label] = dict(t=t, iters=int(res.iters),
                               relres=float(res.relres),
                               switch_iters=np.asarray(res.switch_iters))
        m = None
        if precond != "none":
            # Stepped preconditioned rows: the GSE-packed preconditioner
            # rides the same tag schedule as the operator (one stored
            # copy each); PCG on the fused path, GMRES right-precond.
            m = _PRECOND_FACTORY[precond](a, k=8)
            if kind == "cg":
                res, t = _timed(solve_pcg, g, b, precond=m, **kw)
            else:
                res, t = _timed(solve_gmres, gse_op, b, precond=m, **kw)
            rows["gse_pcg"] = dict(t=t, iters=int(res.iters),
                                   relres=float(res.relres),
                                   switch_iters=np.asarray(res.switch_iters))
        # Paper Eq. 7: GSE-SEM* projection (conversion-free hardware).
        if rows["fp16"]["iters"] > 0:
            t_star = (rows["fp16"]["t"] / rows["fp16"]["iters"]
                      * rows["gse"]["iters"])
        else:
            t_star = rows["gse"]["t"]
        rows["gse_star"] = dict(t=t_star, iters=rows["gse"]["iters"],
                                relres=rows["gse"]["relres"],
                                switch_iters=rows["gse"]["switch_iters"])
        base = rows["fp64"]["t"]
        # Bytes-modeled speedup from the containers' measured-model
        # accounting (the bandwidth-bound quantity that holds on TPU/GPU;
        # CPU wall time here is decode-overhead-dominated and a weak
        # proxy).  The stepped GSE runs charge each iteration the bytes of
        # the tag it actually ran at, split by the recorded switch points.
        store = {"fp64": jnp.float64, "fp16": jnp.float16,
                 "bf16": jnp.bfloat16}
        lay = None
        if layout == "sell":
            from repro.kernels.ops import sell_pack_gsecsr

            lay = sell_pack_gsecsr(g)
        run_bytes = {}
        for label, r in rows.items():
            if label in store:
                run_bytes[label] = (a.bytes_touched(store[label])
                                    * max(r["iters"], 1))
            else:
                # "gse_pcg" additionally charges the preconditioner
                # stream at the per-iteration tag actually run.
                run_bytes[label] = _gse_run_bytes(
                    g, r["iters"], r["switch_iters"],
                    precond=m if label == "gse_pcg" else None,
                    layout=lay)
        for label, r in rows.items():
            modeled = run_bytes["fp64"] / max(run_bytes[label], 1)
            per_it = run_bytes[label] / max(r["iters"], 1) / max(a.nnz, 1)
            # SpMV-dominant roofline fraction for the whole solve: useful
            # FLOPs 2*nnz per iteration over the modeled run bytes (axpy
            # and dot streams ride inside the fused iteration and are not
            # credited -- a conservative floor).
            frac = rl.fraction(2.0 * a.nnz * max(r["iters"], 1),
                               run_bytes[label], max(r["t"], 1e-12), roof)
            r["roofline_fraction"] = frac
            emit(f"fig89/{kind}/{name}/{label}", r["t"] * 1e6,
                 f"iters={r['iters']} speedup={base / max(r['t'],1e-12):.2f}"
                 f" modeled_speedup={modeled:.2f} B/nnz/iter={per_it:.2f}"
                 f" roofline={frac:.3f}")
        if nrhs > 1 and kind == "cg":
            # Batched multi-RHS row: matrix bytes once per iteration,
            # vector bytes per active column (DESIGN.md §11).
            bt = batched_case(a, g, nrhs, params=_PARAMS,
                              maxiter=kw["maxiter"], seed=seed)
            emit(f"fig89/cg/{name}/gse_batch{nrhs}", bt["t"] * 1e6,
                 f"iters={bt['iters']} "
                 f"B/iter(nrhs={nrhs})={bt['bytes_per_iter_nrhs']} "
                 f"B/iter(1)={bt['bytes_per_iter_1']} "
                 f"ratio={bt['per_iter_ratio']:.2f} "
                 f"run_bytes={bt['run_bytes']}")
            rows["gse_batch"] = bt
        if shards > 1 and kind == "cg":
            # Distributed row: same matrix stream redistributed across
            # shards + the tag-aware halo wire ladder (DESIGN.md §13).
            dc = dist_case(a, g, shards, params=_PARAMS,
                           maxiter=kw["maxiter"], seed=seed)
            emit(f"fig89/cg/{name}/gse_dist{shards}", dc["t"] * 1e6,
                 f"iters={dc['iters']} relres={dc['relres']:.2e} "
                 f"wire_t1={dc['halo_wire_bytes'][1]} "
                 f"wire_t3={dc['halo_wire_bytes'][3]} "
                 f"byte_sum_identity={dc['byte_sum_identity']} "
                 f"exact_dx={dc['exact_x_maxdiff']:.2e}")
            rows["gse_dist"] = dc
        out[(kind, name)] = rows
    return out


if __name__ == "__main__":
    run()
