"""Paper Figs. 8/9: end-to-end solver wall time + speedup over FP64.

Includes GSE-SEM* (paper Eq. 7): the projected time if format conversion
were free (hardware GSE-SEM support), computed as
TIME_fp16 / ITERS_fp16 * ITERS_gse.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.precision import MonitorParams
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.solvers import (
    make_fixed_operator,
    make_gse_operator,
    solve_cg,
    solve_gmres,
)

_PARAMS = MonitorParams(t=40, l=60, m=30, rsd_limit=0.5, reldec_limit=0.45)


def _timed(solver, op, b, **kw):
    res = solver(op, b, **kw)  # warm compile
    jax.block_until_ready(res.x)
    t0 = time.perf_counter()
    res = solver(op, b, **kw)
    jax.block_until_ready(res.x)
    return res, time.perf_counter() - t0


def run() -> dict:
    out = {}
    cases = []
    for i, (name, a) in enumerate(list(G.cg_suite(small=True).items())[:4]):
        if a is None:
            continue
        cases.append(("cg", name, a, i))
    for i, (name, a) in enumerate(list(G.gmres_suite(small=True).items())[:3]):
        cases.append(("gmres", name, a, 100 + i))

    for kind, name, a, seed in cases:
        rng = np.random.default_rng(seed)
        from repro.sparse.spmv import spmv

        b = jnp.asarray(np.asarray(spmv(a, jnp.asarray(
            rng.normal(size=a.shape[1])))))
        g = pack_csr(a, k=8)
        solver = solve_cg if kind == "cg" else solve_gmres
        kw = dict(tol=1e-6, params=_PARAMS)
        kw["maxiter"] = 1500 if kind == "cg" else 2400

        rows = {}
        for label, op in {
            "fp64": make_fixed_operator(a),
            "fp16": make_fixed_operator(a, store_dtype=jnp.float16),
            "bf16": make_fixed_operator(a, store_dtype=jnp.bfloat16),
            "gse": make_gse_operator(g),
        }.items():
            res, t = _timed(solver, op, b, **kw)
            rows[label] = dict(t=t, iters=int(res.iters),
                               relres=float(res.relres))
        # Paper Eq. 7: GSE-SEM* projection (conversion-free hardware).
        if rows["fp16"]["iters"] > 0:
            t_star = (rows["fp16"]["t"] / rows["fp16"]["iters"]
                      * rows["gse"]["iters"])
        else:
            t_star = rows["gse"]["t"]
        rows["gse_star"] = dict(t=t_star, iters=rows["gse"]["iters"],
                                relres=rows["gse"]["relres"])
        base = rows["fp64"]["t"]
        # Bytes-modeled speedup: SpMV value+col stream bytes per nnz
        # (the bandwidth-bound quantity that holds on TPU/GPU; CPU wall
        # time here is decode-overhead-dominated and a weak proxy).
        stream = {"fp64": 12, "fp16": 6, "bf16": 6, "gse": 6, "gse_star": 6}
        it64 = max(rows["fp64"]["iters"], 1)
        for label, r in rows.items():
            modeled = (12 * it64) / (stream[label] * max(r["iters"], 1))
            emit(f"fig89/{kind}/{name}/{label}", r["t"] * 1e6,
                 f"iters={r['iters']} speedup={base / max(r['t'],1e-12):.2f}"
                 f" modeled_speedup={modeled:.2f}")
        out[(kind, name)] = rows
    return out


if __name__ == "__main__":
    run()
